//! The planner subsystem: one owner of "model + profile + epsilon +
//! strategy → plan", built for *continuous* replanning as both the
//! uplink **and** the observed exit behaviour fluctuate (the on-demand
//! co-inference regime Edgent argues for: cheap re-optimization on
//! every bandwidth sample — and, since the split depends on the branch
//! exit probability `p` just as much as on bandwidth, cheap
//! re-optimization on every drift of the observed exit rate too).
//!
//! # Why a prefix-sum sweep solves the paper's shortest-path problem
//!
//! The paper reduces BranchyNet partitioning to a shortest `input →
//! output` path in `G'_BDNN` (Eqs. 7–8). The compact construction
//! (`partition::compact`) already observes that once a path cuts to the
//! cloud after stage `s`, no further decision exists — the remaining
//! cost is a constant for that cut. The [`Planner`] takes the final
//! step: it never builds a graph at all. For a split after stage `s`
//! (0 = cloud-only, N = edge-only), Eq. 5 generalized to any number of
//! branches is
//!
//! ```text
//! E[T(s)] =  A(s)  +  S(s) · ( alpha_s/B + rtt + C(s) )
//!
//! A(s) = Σ_{i≤s} S(before i) · t_i^e   [+ Σ_{b_j < s} S_j · t_b^e]
//! S(s) = Π_{b_j < s} (1 − p_j)            (survival at the cut, Eq. 4)
//! C(s) = Σ_{i>s} t_i^c                    (cloud suffix, Eq. 2)
//! ```
//!
//! # Bits-aware alpha
//!
//! `alpha_s` is not a property of the model alone — it is what the
//! deployment actually puts on the wire. With a quantized transfer
//! codec (`network::encoding`), the 4-byte f32 activations ship as 1-
//! or ½-byte codes plus an 8-byte scale/zero header, so the transfer
//! term shrinks ~4x (q8) or ~8x (q4) and the optimal split can
//! *relocate* — typically toward the cloud, since shipping earlier
//! (bigger) activations stops being prohibitive. [`StaticCore`] bakes
//! `alpha_s = desc.transfer_wire_bytes(s, encoding)` at construction;
//! [`Planner::with_wire_encoding`] re-bakes the core under a different
//! encoding (sharing the live exit view), and both the planner and
//! [`crate::timing::Estimator::with_encoding`] price sizes through the
//! single [`crate::network::WireEncoding::payload_bytes`] map the codec
//! ships with — so the cost model and the wire can't disagree, and the
//! planner stays bit-identical to the brute-force oracle at every
//! encoding (property-tested below).
//!
//! # The two-layer core: `StaticCore` + `ExitView`
//!
//! The precomputed state splits along its *dependencies*:
//!
//! * **`StaticCore`** — everything that is a pure function of the model
//!   description and the measured profile: raw per-stage edge times,
//!   the cloud suffix sums `C(·)`, the transfer sizes `alpha_s`, the
//!   branch positions and `branch_t_edge`. Immutable, validated once,
//!   shared by every [`Planner::fork`] and every p-variant behind one
//!   `Arc` — a fleet pays for it exactly once per (model, profile).
//! * **`ExitView`** — everything that additionally depends on the
//!   branch exit probabilities `p`: the survival-weighted prefix sums
//!   `A(·)` and the survival products `S(·)`. Deriving a view is one
//!   O(N·m) pass over the core with **no desc clone, no re-validation
//!   and no graph work** — so [`Planner::with_exit_probs`] (a sibling
//!   planner at different p) and [`Planner::set_exit_probs`] (swap the
//!   live view in place, e.g. from an online exit-rate estimator) are
//!   both cheap enough to run inside a serving loop. Every view swap
//!   bumps an **epoch counter**; plan caches are epoch-checked so no
//!   stale plan survives a p-update (see [`cache::PlanCache`]).
//!
//! A `plan_for(link)` query is a pure O(N) arithmetic sweep over the
//! two layers: evaluate `E[T(s)]` for every `s`, add the paper's
//! epsilon tie-breaker to the cut options (so exact ties resolve toward
//! the edge, exactly as the `(v*c, output)` epsilon link does in §V),
//! and take the argmin. No graph rebuild, no Dijkstra heap, no
//! allocation beyond the returned plan.
//!
//! The sweep reproduces [`crate::timing::Estimator::expected_time`]
//! operation-for-operation (same fold order), so the reported
//! `expected_time_s` is bit-identical to what the paper-faithful
//! oracle [`crate::partition::solver::solve_faithful`] reports for the
//! same split — and a view derived by `with_exit_probs(p)` is
//! bit-identical to a fresh `Planner::new` at the same p. Both are
//! property-tested in `rust/tests/planner_equivalence.rs`.
//!
//! On top of the sweep sit three feedback layers:
//!
//! * [`cache::PlanCache`] — plans memoized by *log-bucketed* bandwidth
//!   (default ~24 buckets per decade ≈ 10% quantization) with hit/miss
//!   counters and epoch-based invalidation, so a jittering-but-stable
//!   uplink costs a hash lookup and a p-update costs one re-solve per
//!   bucket;
//! * [`adaptive`] — the bandwidth replan loop: it consumes bandwidth
//!   estimates (e.g. `network::trace` through a `Channel`), applies
//!   hysteresis so the split doesn't flap between adjacent buckets,
//!   and drives [`crate::coordinator::Coordinator::set_plan`];
//! * [`estimator`] — the exit-rate feedback state machine: an EWMA
//!   over per-request exited-early observations that triggers a view
//!   rebuild when the estimate drifts beyond a configurable threshold
//!   (the fleet feeds it from the coordinator's branch gate);
//! * [`joint`] — the joint configuration search
//!   ([`Planner::plan_joint`]): the same O(N) sweep run once per
//!   (branch-set, wire-encoding) candidate over one shared
//!   `StaticCore`, pruned by an accuracy-proxy floor — the first
//!   optimizer here that moves more than the split axis;
//! * [`chain`] — the K-tier generalization
//!   ([`Planner::plan_chain`]): a monotone cut *vector* over a
//!   [`TierChain`] of per-hop links and per-tier compute scales,
//!   solved as a layered dynamic program in O(K·N²) over the same
//!   prefix/suffix tables; K = 2 collapses bit-identically to
//!   [`Planner::plan_for`], and the exhaustive cut-vector oracle
//!   (`rust/tests/ktier_optimality.rs`) holds every K to the
//!   brute-force argmin.

pub mod adaptive;
pub mod cache;
pub mod chain;
pub mod estimator;
pub mod joint;

pub use adaptive::{AdaptiveConfig, AdaptiveHandle, AdaptivePlanner, ReplanState, ReplanStats};
pub use cache::PlanCache;
pub use chain::{ChainPlan, TierChain};
pub use estimator::{EstimatorConfig, ExitRateEstimator};
pub use joint::{JointCandidate, JointPlan, JointSearchSpace};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::config::settings::Strategy;
use crate::model::BranchyNetDesc;
use crate::network::bandwidth::LinkModel;
use crate::network::encoding::WireEncoding;
use crate::partition::plan::PartitionPlan;
use crate::timing::profile::DelayProfile;

/// The immutable p-independent precompute shared by a planner, all its
/// [`Planner::fork`]s and all its [`Planner::with_exit_probs`]
/// siblings: a pure function of (model, profile, mode) — raw stage
/// times, transfer sizes and branch geometry, nothing survival-weighted.
#[derive(Debug)]
struct StaticCore {
    desc: BranchyNetDesc,
    paper_mode: bool,
    n: usize,
    /// Raw per-stage edge times (profile.t_edge), unweighted.
    t_edge: Vec<f64>,
    /// Branch-evaluation time on the edge (serving mode only).
    branch_t_edge: f64,
    /// 1-based branch positions, sorted ascending.
    branch_positions: Vec<usize>,
    /// For each split s, how many branches are *active* (position < s):
    /// precomputed so a view derivation does no binary searches.
    active_at: Vec<usize>,
    /// C(s): cloud time of stages s+1..=N.
    cloud_suffix: Vec<f64>,
    /// alpha_s as it crosses the uplink for a cut after stage s
    /// (s < N): `desc.transfer_wire_bytes(s, wire_encoding)` — the raw
    /// activation size pushed through the configured encoding's size
    /// map, so compressed deployments plan against what they ship.
    alpha_bytes: Vec<u64>,
    /// The encoding `alpha_bytes` was baked under.
    wire_encoding: WireEncoding,
}

/// The p-dependent layer: survival-weighted folds over a [`StaticCore`],
/// derived in one O(N·m) pass by [`ExitView::derive`]. Bit-identical to
/// what a fresh construction at the same p computes (same fold order).
#[derive(Debug)]
struct ExitView {
    /// Conditional exit probability per branch, in branch-position order.
    exit_probs: Vec<f64>,
    /// A(s): survival-weighted edge compute through stage s, plus (in
    /// serving mode) the survival-weighted branch-evaluation terms —
    /// folded in the same order as `Estimator::expected_time`.
    edge_cost: Vec<f64>,
    /// S(s): survival probability at a cut after stage s.
    surv: Vec<f64>,
}

impl ExitView {
    /// One O(N·m) pass: survival chain, then the edge-cost fold, then
    /// the survival-at-split table. The arithmetic (operations *and*
    /// their order) mirrors `Estimator::expected_time` exactly, which is
    /// what makes `with_exit_probs(p)` bit-identical to `Planner::new`
    /// at the same p.
    fn derive(core: &StaticCore, probs: &[f64]) -> ExitView {
        assert_eq!(
            probs.len(),
            core.branch_positions.len(),
            "expected {} exit probabilities (one per branch), got {}",
            core.branch_positions.len(),
            probs.len()
        );
        ExitView::derive_for(core, &core.active_at, probs)
    }

    /// [`ExitView::derive`] generalized to a *candidate* branch geometry
    /// over the same core: `active_at` must be the `partition_point`
    /// table of the candidate's sorted 1-based positions (so
    /// `active_at[s]` counts candidate branches strictly before split
    /// `s`) and `probs` its conditional exit probabilities in the same
    /// order. The joint search ([`joint`]) uses this to price branch-set
    /// candidates without cloning or re-validating the desc; with the
    /// core's own tables it is exactly `derive` (same operations, same
    /// fold order — that identity is what keeps the restricted joint
    /// search bit-identical to [`Planner::plan_for`]).
    fn derive_for(core: &StaticCore, active_at: &[usize], probs: &[f64]) -> ExitView {
        for &p in probs {
            assert!(
                (0.0..=1.0).contains(&p),
                "exit probability {p} not in [0, 1]"
            );
        }
        let n = core.n;
        assert_eq!(active_at.len(), n + 1, "active_at must cover splits 0..=N");
        assert_eq!(
            active_at[n],
            probs.len(),
            "every branch position must lie strictly before stage N"
        );
        // survival[j] = P[not exited at any of the first j branches].
        let mut survival = Vec::with_capacity(probs.len() + 1);
        survival.push(1.0f64);
        for &p in probs {
            let last = *survival.last().unwrap();
            survival.push(last * (1.0 - p));
        }

        // Prefix sums of survival-weighted edge times. Incremental
        // left-fold, so edge_cost[s] carries exactly the partial sums
        // the estimator's edge loop would produce for split s.
        let mut edge_cost = vec![0.0f64; n + 1];
        for i in 1..=n {
            edge_cost[i] = edge_cost[i - 1] + survival[active_at[i]] * core.t_edge[i - 1];
        }
        // Branch-evaluation terms are folded *after* the edge sum
        // (mirroring the estimator's second loop) so the fp result
        // stays identical to a direct `expected_time` evaluation.
        if !core.paper_mode {
            for s in 0..=n {
                let mut t = edge_cost[s];
                // One term per *active* branch (position < s), in branch
                // order, each weighted by the survival of reaching it.
                for &reach in &survival[..active_at[s]] {
                    t += reach * core.branch_t_edge;
                }
                edge_cost[s] = t;
            }
        }

        let surv: Vec<f64> = (0..=n).map(|s| survival[active_at[s]]).collect();

        ExitView {
            exit_probs: probs.to_vec(),
            edge_cost,
            surv,
        }
    }
}

/// The live, swappable view slot shared by a planner and its forks:
/// the current [`ExitView`] plus the epoch counter that invalidates
/// plan caches when the view changes.
#[derive(Debug)]
struct SharedView {
    view: RwLock<Arc<ExitView>>,
    /// Bumped on every [`Planner::set_exit_probs`]; plan caches compare
    /// against it so a stale bucket can never serve a pre-update plan.
    epoch: AtomicU64,
    /// How many times the view has been re-derived in place.
    rebuilds: AtomicU64,
}

impl SharedView {
    fn new(view: ExitView) -> SharedView {
        SharedView {
            view: RwLock::new(Arc::new(view)),
            epoch: AtomicU64::new(0),
            rebuilds: AtomicU64::new(0),
        }
    }
}

/// Precomputed planning state for one (model, profile, epsilon, mode)
/// tuple at the current exit probabilities. Construction is O(N·m);
/// each [`Planner::plan_for`] is an O(N) sweep and each
/// [`Planner::expected_time`] query is O(1).
///
/// The p-independent sums live behind one `Arc`'d `StaticCore`; the
/// p-dependent folds behind a swappable `ExitView`:
///
/// * [`Planner::fork`] — same core, **same live view** (a fork sees
///   every [`Planner::set_exit_probs`] on the original, and vice
///   versa), its own [`PlanCache`]. One per consumer of a class.
/// * [`Planner::with_exit_probs`] — same core, **new independent view**
///   at different p, its own cache. One per link class in a fleet.
/// * [`Planner::set_exit_probs`] — re-derive the live view in place
///   (O(N·m), no desc clone, no validation, no graph work) and bump the
///   view epoch so every sharing planner's cache re-solves its buckets.
///
/// The planner is `Send + Sync` and can be moved into a replan thread.
#[derive(Debug)]
pub struct Planner {
    core: Arc<StaticCore>,
    shared: Arc<SharedView>,
    epsilon: f64,
    cache: PlanCache,
}

impl Planner {
    /// Precompute all link-independent state. `paper_mode = true`
    /// reproduces Eq. 5 exactly (no branch-evaluation cost); `false` is
    /// the serving default — the same convention as
    /// [`crate::partition::solver::solve`].
    ///
    /// Panics on an invalid description/profile pair or a non-positive
    /// epsilon, like the estimator and the graph constructions do.
    pub fn new(
        desc: &BranchyNetDesc,
        profile: &DelayProfile,
        epsilon: f64,
        paper_mode: bool,
    ) -> Planner {
        desc.validate().expect("invalid BranchyNet description");
        profile
            .validate(desc.num_stages())
            .expect("profile/desc mismatch");
        assert!(
            epsilon > 0.0 && epsilon.is_finite(),
            "epsilon must be positive (paper §V)"
        );

        let n = desc.num_stages();
        // Sort branches by position (stable, like `ExitChain`): the
        // survival chain and every probs slice use this order.
        let mut branches: Vec<(usize, f64)> = desc
            .branches
            .iter()
            .map(|b| (b.after_stage, b.exit_prob))
            .collect();
        branches.sort_by_key(|&(pos, _)| pos);
        let branch_positions: Vec<usize> = branches.iter().map(|&(p, _)| p).collect();
        let probs: Vec<f64> = branches.iter().map(|&(_, p)| p).collect();
        let active_at: Vec<usize> = (0..=n)
            .map(|s| branch_positions.partition_point(|&pos| pos < s))
            .collect();

        // Suffix sums of cloud times, accumulated back-to-front exactly
        // like `timing::profile::CloudSuffix`.
        let mut cloud_suffix = vec![0.0f64; n + 1];
        for i in (0..n).rev() {
            cloud_suffix[i] = cloud_suffix[i + 1] + profile.t_cloud[i];
        }

        let alpha_bytes: Vec<u64> = (0..n).map(|s| desc.transfer_bytes(s)).collect();

        let core = Arc::new(StaticCore {
            desc: desc.clone(),
            paper_mode,
            n,
            t_edge: profile.t_edge.clone(),
            branch_t_edge: profile.branch_t_edge,
            branch_positions,
            active_at,
            cloud_suffix,
            alpha_bytes,
            wire_encoding: WireEncoding::Raw,
        });
        let view = ExitView::derive(&core, &probs);

        Planner {
            core,
            shared: Arc::new(SharedView::new(view)),
            epsilon,
            cache: PlanCache::default(),
        }
    }

    /// A planner sharing this one's precomputed core **and live view**
    /// (a [`Planner::set_exit_probs`] on either is seen by both) but
    /// with its own empty [`PlanCache`] and cache counters — one per
    /// consumer thread of the same link class.
    pub fn fork(&self) -> Planner {
        let cache = PlanCache::default();
        cache.seed_epoch(self.shared.epoch.load(Ordering::Acquire));
        Planner {
            core: self.core.clone(),
            shared: self.shared.clone(),
            epsilon: self.epsilon,
            cache,
        }
    }

    /// A planner sharing this one's `StaticCore` but with an
    /// **independent** `ExitView` derived at `probs` (one conditional
    /// probability per branch, in branch-position order): one O(N·m)
    /// pass — no desc clone, no re-validation, no graph work — and
    /// bit-identical to a fresh [`Planner::new`] at the same p. One per
    /// link class in a fleet.
    ///
    /// Panics if `probs` has the wrong length or values outside [0, 1].
    ///
    /// # Example
    ///
    /// ```
    /// use branchyserve::model::{BranchDesc, BranchyNetDesc};
    /// use branchyserve::network::bandwidth::LinkModel;
    /// use branchyserve::planner::Planner;
    /// use branchyserve::timing::DelayProfile;
    ///
    /// let desc = BranchyNetDesc {
    ///     stage_names: vec!["conv1".into(), "conv2".into(), "fc".into()],
    ///     stage_out_bytes: vec![40_000, 8_000, 8],
    ///     input_bytes: 12_288,
    ///     branches: vec![BranchDesc { after_stage: 1, exit_prob: 0.5 }],
    /// };
    /// let profile = DelayProfile::from_cloud_times(vec![1e-4, 2e-4, 5e-5], 2e-5, 100.0);
    /// let base = Planner::new(&desc, &profile, 1e-9, false);
    ///
    /// // A sibling view for a class whose traffic exits 90% of the
    /// // time: the expensive precompute is shared, only the cheap
    /// // survival-weighted folds are re-derived.
    /// let optimistic = base.with_exit_probs(&[0.9]);
    /// assert!(base.shares_core_with(&optimistic));
    /// assert!(!base.shares_view_with(&optimistic));
    /// assert_eq!(optimistic.exit_probs(), vec![0.9]);
    ///
    /// // Both plan independently at their own p.
    /// let link = LinkModel::new(5.85, 0.0);
    /// let _plan = optimistic.plan_for(link);
    /// assert_eq!(base.exit_probs(), vec![0.5], "base view untouched");
    /// ```
    pub fn with_exit_probs(&self, probs: &[f64]) -> Planner {
        let view = ExitView::derive(&self.core, probs);
        Planner {
            core: self.core.clone(),
            shared: Arc::new(SharedView::new(view)),
            epsilon: self.epsilon,
            cache: PlanCache::default(),
        }
    }

    /// A planner whose transfer sizes are re-baked under `encoding`:
    /// `alpha_s` becomes [`BranchyNetDesc::transfer_wire_bytes`]`(s,
    /// encoding)`, so [`Planner::plan_for`] solves for the split that is
    /// optimal *given* what the codec actually ships. The exit view
    /// stays **shared live** (alpha is p-independent): a
    /// [`Planner::set_exit_probs`] on either planner is seen by both.
    /// O(N) — only the alpha table is recomputed; every other core
    /// field is cloned.
    pub fn with_wire_encoding(&self, encoding: WireEncoding) -> Planner {
        let old = &*self.core;
        let core = Arc::new(StaticCore {
            desc: old.desc.clone(),
            paper_mode: old.paper_mode,
            n: old.n,
            t_edge: old.t_edge.clone(),
            branch_t_edge: old.branch_t_edge,
            branch_positions: old.branch_positions.clone(),
            active_at: old.active_at.clone(),
            cloud_suffix: old.cloud_suffix.clone(),
            alpha_bytes: (0..old.n)
                .map(|s| old.desc.transfer_wire_bytes(s, encoding))
                .collect(),
            wire_encoding: encoding,
        });
        let cache = PlanCache::default();
        cache.seed_epoch(self.shared.epoch.load(Ordering::Acquire));
        Planner {
            core,
            shared: self.shared.clone(),
            epsilon: self.epsilon,
            cache,
        }
    }

    /// The wire encoding this planner's transfer sizes are baked under.
    pub fn wire_encoding(&self) -> WireEncoding {
        self.core.wire_encoding
    }

    /// Re-derive the live view at `probs` and swap it in, in place —
    /// this planner *and every fork sharing the view* observe the new
    /// probabilities on their next query, and the bumped view epoch
    /// makes every sharing [`PlanCache`] re-solve its buckets (a
    /// previously hit bucket misses exactly once, then re-populates
    /// under the new p). O(N·m); cheap enough for a serving loop.
    ///
    /// Panics if `probs` has the wrong length or values outside [0, 1].
    pub fn set_exit_probs(&self, probs: &[f64]) {
        let view = Arc::new(ExitView::derive(&self.core, probs));
        *self.shared.view.write().unwrap() = view;
        self.shared.rebuilds.fetch_add(1, Ordering::Relaxed);
        // Release-order after the view install: an epoch observer that
        // sees the new epoch also sees the new view.
        self.shared.epoch.fetch_add(1, Ordering::Release);
    }

    /// The conditional exit probabilities of the current view, in
    /// branch-position order.
    pub fn exit_probs(&self) -> Vec<f64> {
        self.view().exit_probs.clone()
    }

    /// The current view epoch: 0 at construction, +1 per
    /// [`Planner::set_exit_probs`] on this planner or any fork.
    pub fn view_epoch(&self) -> u64 {
        self.shared.epoch.load(Ordering::Acquire)
    }

    /// How many times the live view has been re-derived in place.
    pub fn view_rebuilds(&self) -> u64 {
        self.shared.rebuilds.load(Ordering::Relaxed)
    }

    /// True if `other` shares this planner's p-independent core (i.e.
    /// one is a [`Planner::fork`] or [`Planner::with_exit_probs`]
    /// sibling of the other).
    pub fn shares_core_with(&self, other: &Planner) -> bool {
        Arc::ptr_eq(&self.core, &other.core)
    }

    /// True if `other` additionally shares the *live view* — i.e. a
    /// [`Planner::set_exit_probs`] on one is seen by the other.
    pub fn shares_view_with(&self, other: &Planner) -> bool {
        Arc::ptr_eq(&self.shared, &other.shared)
    }

    pub fn desc(&self) -> &BranchyNetDesc {
        &self.core.desc
    }

    pub fn num_stages(&self) -> usize {
        self.core.n
    }

    pub fn epsilon(&self) -> f64 {
        self.epsilon
    }

    pub fn paper_mode(&self) -> bool {
        self.core.paper_mode
    }

    fn view(&self) -> Arc<ExitView> {
        self.shared.view.read().unwrap().clone()
    }

    /// The sweep kernel: E[T(split)] under `link` for one pinned view.
    #[inline]
    fn expected_time_in(&self, view: &ExitView, split: usize, link: LinkModel) -> f64 {
        let core = &*self.core;
        assert!(split <= core.n, "split {split} out of range 0..={}", core.n);
        let mut t = view.edge_cost[split];
        if split < core.n {
            let surv = view.surv[split];
            if surv > 0.0 {
                t += surv
                    * (link.transfer_time(core.alpha_bytes[split]) + core.cloud_suffix[split]);
            }
        }
        t
    }

    /// `E[T_inf]` for a split after stage `split` under `link` — O(1),
    /// and bit-identical to `Estimator::expected_time` for the same
    /// mode and exit probabilities (same terms, same fold order).
    pub fn expected_time(&self, split: usize, link: LinkModel) -> f64 {
        let view = self.view();
        self.expected_time_in(&view, split, link)
    }

    /// Solve for the optimal split under `link`: an O(N) sweep over the
    /// precomputed state. Cut options carry the epsilon tie-breaker
    /// (paper §V), so exact ties resolve toward keeping work on the
    /// edge — the same direction as the graph solvers and the
    /// brute-force oracle.
    pub fn plan_for(&self, link: LinkModel) -> PartitionPlan {
        self.plan_with_epsilon(link, self.epsilon)
    }

    /// [`Planner::plan_for`] with an explicit tie-breaker. The
    /// precomputed state is epsilon-independent, so epsilon-sensitivity
    /// sweeps (the ablation) pay one precompute and K O(N) sweeps
    /// instead of K full constructions. Bypasses the plan cache. The
    /// view is pinned once for the whole sweep, so a concurrent
    /// [`Planner::set_exit_probs`] can never mix two p's in one plan.
    pub fn plan_with_epsilon(&self, link: LinkModel, epsilon: f64) -> PartitionPlan {
        assert!(
            epsilon > 0.0 && epsilon.is_finite(),
            "epsilon must be positive (paper §V)"
        );
        let view = self.view();
        let n = self.core.n;
        let mut best_split = 0usize;
        let mut best_model = f64::INFINITY;
        let mut best_decision = f64::INFINITY;
        for s in 0..=n {
            let model = self.expected_time_in(&view, s, link);
            let decision = if s < n { model + epsilon } else { model };
            // `<=`: on an exact tie the larger split (more edge work) wins.
            if decision <= best_decision {
                best_decision = decision;
                best_model = model;
                best_split = s;
            }
        }
        PartitionPlan::from_split_encoded(
            best_split,
            best_model,
            Strategy::ShortestPath,
            &self.core.desc,
            self.core.wire_encoding,
        )
    }

    /// Like [`Planner::plan_for`], but memoized by quantized bandwidth:
    /// the link is log-bucketed (see [`PlanCache`]) and the plan is
    /// computed once per bucket, at the bucket's representative
    /// bandwidth. Repeated samples from a jittering-but-stable uplink
    /// are cache hits; a view swap ([`Planner::set_exit_probs`])
    /// invalidates every bucket via the view epoch.
    pub fn plan_cached(&self, link: LinkModel) -> PartitionPlan {
        let epoch = self.shared.epoch.load(Ordering::Acquire);
        self.cache
            .get_or_insert_at_epoch(link, epoch, |rep| self.plan_for(rep))
    }

    /// The representative link `plan_cached` would actually solve for.
    pub fn cache_representative(&self, link: LinkModel) -> LinkModel {
        self.cache.representative(self.cache.key_for(link))
    }

    /// (hits, misses) of the plan cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    /// How many times this planner's cache was flushed by a view-epoch
    /// change.
    pub fn cache_invalidations(&self) -> u64 {
        self.cache.invalidations()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic, BranchDesc};
    use crate::partition::brute;
    use crate::testing::property;
    use crate::timing::Estimator;

    fn fixture(p: f64) -> (BranchyNetDesc, DelayProfile) {
        let desc = BranchyNetDesc {
            stage_names: (1..=5).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![57_600, 18_816, 25_088, 3_456, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: p,
            }],
        };
        let profile = DelayProfile::from_cloud_times(
            vec![1e-3, 2e-3, 1.5e-3, 8e-4, 2e-4],
            3e-4,
            100.0,
        );
        (desc, profile)
    }

    #[test]
    fn expected_time_is_bit_identical_to_estimator() {
        property("planner == estimator, bitwise", 150, |g| {
            let n = g.usize_in(1, 30);
            let desc = synthetic::random_desc(g, n, 4);
            let gamma = g.f64_in(1.0, 1000.0);
            let profile = synthetic::random_profile(g, &desc, gamma);
            let link = LinkModel::new(g.f64_in(0.05, 100.0), g.f64_in(0.0, 0.05));
            let paper = g.bool(0.5);

            let planner = Planner::new(&desc, &profile, 1e-9, paper);
            let est = Estimator::new(&desc, &profile, link);
            let est = if paper { est.paper_mode() } else { est };
            for s in 0..=n {
                let a = planner.expected_time(s, link);
                let b = est.expected_time(s);
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "split {s}: planner {a} vs estimator {b} (n={n}, paper={paper})"
                );
            }
        });
    }

    #[test]
    fn plan_for_matches_brute_force_within_epsilon() {
        const EPS: f64 = 1e-9;
        property("planner == brute force", 200, |g| {
            let n = g.usize_in(1, 24);
            let desc = synthetic::random_desc(g, n, 3);
            let profile = synthetic::random_profile(g, &desc, g.f64_in(1.0, 2000.0));
            let link = LinkModel::new(g.f64_in(0.05, 100.0), g.f64_in(0.0, 0.02));
            let paper = g.bool(0.5);

            let planner = Planner::new(&desc, &profile, EPS, paper);
            let plan = planner.plan_for(link);
            let est = Estimator::new(&desc, &profile, link);
            let est = if paper { est.paper_mode() } else { est };
            let bf = brute::solve(&est);
            assert!(
                (plan.expected_time_s - bf.expected_time_s).abs()
                    <= EPS + 1e-12 * bf.expected_time_s.max(1.0),
                "planner {} vs brute {} (n={n})",
                plan.expected_time_s,
                bf.expected_time_s
            );
            // The reported split must achieve the reported time exactly.
            assert_eq!(
                planner.expected_time(plan.split_after, link).to_bits(),
                plan.expected_time_s.to_bits()
            );
        });
    }

    #[test]
    fn encoded_planner_is_bit_identical_to_encoded_brute_force() {
        const EPS: f64 = 1e-9;
        property("planner(enc) == brute(estimator(enc)), bitwise", 120, |g| {
            let n = g.usize_in(1, 24);
            let desc = synthetic::random_desc(g, n, 3);
            let profile = synthetic::random_profile(g, &desc, g.f64_in(1.0, 2000.0));
            let link = LinkModel::new(g.f64_in(0.05, 100.0), g.f64_in(0.0, 0.02));
            let paper = g.bool(0.5);

            let base = Planner::new(&desc, &profile, EPS, paper);
            for enc in WireEncoding::ALL {
                let planner = base.with_wire_encoding(enc);
                assert_eq!(planner.wire_encoding(), enc);
                let est = Estimator::new(&desc, &profile, link).with_encoding(enc);
                let est = if paper { est.paper_mode() } else { est };
                // The sweep kernel must agree with the encoding-aware
                // oracle bit for bit at every split...
                for s in 0..=n {
                    assert_eq!(
                        planner.expected_time(s, link).to_bits(),
                        est.expected_time(s).to_bits(),
                        "split {s} under {enc} (n={n}, paper={paper})"
                    );
                }
                // ...and the solved plan must match the brute-force
                // argmin over that oracle up to the epsilon tie-break,
                // achieving its reported time exactly.
                let plan = planner.plan_for(link);
                let bf = brute::solve(&est);
                assert!(
                    (plan.expected_time_s - bf.expected_time_s).abs()
                        <= EPS + 1e-12 * bf.expected_time_s.max(1.0),
                    "{enc}: planner {} vs brute {} (n={n})",
                    plan.expected_time_s,
                    bf.expected_time_s
                );
                assert_eq!(
                    planner.expected_time(plan.split_after, link).to_bits(),
                    plan.expected_time_s.to_bits()
                );
            }
            // Raw is the identity: same alphas as the base planner.
            let raw = base.with_wire_encoding(WireEncoding::Raw);
            for s in 0..=n {
                assert_eq!(
                    raw.expected_time(s, link).to_bits(),
                    base.expected_time(s, link).to_bits()
                );
            }
        });
    }

    #[test]
    fn compression_relocates_the_optimal_split_on_a_transfer_dominated_link() {
        // Two stages, megabyte activations, a 1 Mbps uplink, and a
        // cloud 20x faster than the edge: raw transfer costs ~8 s, so
        // the optimum is to stay on the edge (~2 s) — unless the codec
        // shrinks the upload enough to make the fast cloud reachable.
        let desc = BranchyNetDesc {
            stage_names: vec!["s1".into(), "s2".into()],
            stage_out_bytes: vec![1_000_000, 8],
            input_bytes: 1_000_000,
            branches: vec![],
        };
        // gamma = 20: t_edge = [0.01, 2.0], t_cloud = [0.0005, 0.1].
        let profile = DelayProfile::from_cloud_times(vec![0.0005, 0.1], 0.0, 20.0);
        let link = LinkModel::new(1.0, 0.0);

        let base = Planner::new(&desc, &profile, 1e-9, false);
        // Raw: 8 s + cloud > 2.01 s edge-only.
        assert_eq!(base.plan_for(link).split_after, 2, "raw: stay on the edge");
        // q8 (4x): 2.0 s transfer + 0.1 s cloud still loses to 2.01 s
        // edge-only — compression alone does not automatically move the
        // split; the solver has to *prove* it pays.
        let q8 = base.with_wire_encoding(WireEncoding::Q8);
        assert_eq!(q8.plan_for(link).split_after, 2, "q8: still not worth it");
        // q4 (8x): ~1 s transfer + fast cloud beats the edge; the
        // optimum relocates all the way to cloud-only.
        let q4 = base.with_wire_encoding(WireEncoding::Q4);
        assert_eq!(q4.plan_for(link).split_after, 0, "q4: offload everything");
        assert!(q4.plan_for(link).expected_time_s < base.plan_for(link).expected_time_s);
    }

    #[test]
    fn plan_wire_bytes_report_the_minimized_quantity() {
        // The encoding-drift pin: an encoded planner's plan must
        // summarize the wire size it actually priced, while the raw
        // model size stays available alongside it.
        let (desc, profile) = fixture(0.5);
        let base = Planner::new(&desc, &profile, 1e-9, false);
        let link = LinkModel::new(5.85, 0.0);
        for enc in WireEncoding::ALL {
            let plan = base.with_wire_encoding(enc).plan_for(link);
            let s = plan.split_after;
            // gamma = 100: the slow edge guarantees an offloading split,
            // so the byte fields are live (never the edge-only zeros).
            assert!(s < 5, "expected an offloading split under {enc:?}, got {s}");
            assert_eq!(plan.transfer_bytes, desc.transfer_bytes(s), "{enc:?}");
            assert_eq!(plan.wire_bytes, desc.transfer_wire_bytes(s, enc), "{enc:?}");
        }
        // Quantized plans genuinely diverge from the raw size — the pin
        // can't pass vacuously.
        let q8 = base.with_wire_encoding(WireEncoding::Q8).plan_for(link);
        assert!(
            q8.wire_bytes < q8.transfer_bytes,
            "q8 wire {} must undercut raw {}",
            q8.wire_bytes,
            q8.transfer_bytes
        );
        // And the raw planner keeps the identity.
        let raw = base.plan_for(link);
        assert_eq!(raw.wire_bytes, raw.transfer_bytes);
    }

    #[test]
    fn p_one_tie_resolves_toward_edge() {
        // With p = 1 every cut at or past the branch costs exactly the
        // edge prefix through the branch; the epsilon tie-breaker must
        // keep the work on the edge (no spurious zero-cost cloud hop).
        let (desc, profile) = fixture(1.0);
        let planner = Planner::new(&desc, &profile, 1e-9, true);
        let plan = planner.plan_for(LinkModel::new(0.05, 0.0));
        assert!(plan.is_edge_only(5), "{plan:?}");
        assert_eq!(plan.expected_time_s.to_bits(), profile.t_edge[0].to_bits());
    }

    #[test]
    fn cached_plans_hit_within_a_bucket() {
        let (desc, profile) = fixture(0.5);
        let planner = Planner::new(&desc, &profile, 1e-9, false);

        let a = planner.plan_cached(LinkModel::new(5.85, 0.0));
        let (h, m) = planner.cache_stats();
        assert_eq!((h, m), (0, 1));

        // Same bucket (~10% wide): a hit, byte-identical plan.
        let b = planner.plan_cached(LinkModel::new(5.87, 0.0));
        let (h, m) = planner.cache_stats();
        assert_eq!((h, m), (1, 1));
        assert_eq!(a, b);

        // A different decade: a miss.
        let _ = planner.plan_cached(LinkModel::new(58.5, 0.0));
        let (h, m) = planner.cache_stats();
        assert_eq!((h, m), (1, 2));

        // The cached plan is the exact plan at the bucket representative.
        let rep = planner.cache_representative(LinkModel::new(5.87, 0.0));
        assert_eq!(b, planner.plan_for(rep));
    }

    #[test]
    fn fork_shares_sums_but_not_the_cache() {
        let (desc, profile) = fixture(0.5);
        let base = Planner::new(&desc, &profile, 1e-9, false);
        let fork = base.fork();
        assert!(base.shares_core_with(&fork));
        assert!(base.shares_view_with(&fork));

        // Identical math, bit for bit.
        let link = LinkModel::new(5.85, 0.01);
        for s in 0..=base.num_stages() {
            assert_eq!(
                base.expected_time(s, link).to_bits(),
                fork.expected_time(s, link).to_bits()
            );
        }
        assert_eq!(base.plan_for(link), fork.plan_for(link));

        // Cache state is per-instance: a fork's lookups never touch the
        // base planner's counters.
        let _ = fork.plan_cached(link);
        let _ = fork.plan_cached(link);
        assert_eq!(fork.cache_stats(), (1, 1));
        assert_eq!(base.cache_stats(), (0, 0));

        // A fresh construction is not the same core.
        let other = Planner::new(&desc, &profile, 1e-9, false);
        assert!(!base.shares_core_with(&other));
    }

    #[test]
    fn with_exit_probs_is_bit_identical_to_fresh_construction() {
        property("with_exit_probs == Planner::new at same p", 150, |g| {
            let n = g.usize_in(1, 30);
            let mut desc = synthetic::random_desc(g, n, 4);
            let profile = synthetic::random_profile(g, &desc, g.f64_in(1.0, 1000.0));
            let paper = g.bool(0.5);
            let base = Planner::new(&desc, &profile, 1e-9, paper);

            // New probabilities, in branch-position order.
            let probs: Vec<f64> = (0..desc.branches.len()).map(|_| g.probability()).collect();
            let rebuilt = base.with_exit_probs(&probs);
            assert!(base.shares_core_with(&rebuilt));
            assert!(!base.shares_view_with(&rebuilt));

            // Oracle: a fresh, fully validated construction at the same p.
            desc.branches.sort_by_key(|b| b.after_stage);
            for (b, &p) in desc.branches.iter_mut().zip(&probs) {
                b.exit_prob = p;
            }
            let fresh = Planner::new(&desc, &profile, 1e-9, paper);

            for _ in 0..4 {
                let link = LinkModel::new(g.f64_in(0.05, 100.0), g.f64_in(0.0, 0.05));
                for s in 0..=n {
                    assert_eq!(
                        rebuilt.expected_time(s, link).to_bits(),
                        fresh.expected_time(s, link).to_bits(),
                        "split {s} (n={n}, paper={paper}, probs={probs:?})"
                    );
                }
                assert_eq!(rebuilt.plan_for(link), fresh.plan_for(link));
            }
        });
    }

    #[test]
    fn set_exit_probs_swaps_the_view_for_every_fork() {
        let (desc, profile) = fixture(0.9);
        let base = Planner::new(&desc, &profile, 1e-9, false);
        let fork = base.fork();
        let link = LinkModel::new(5.85, 0.0);
        assert_eq!(base.exit_probs(), vec![0.9]);
        assert_eq!(base.view_epoch(), 0);

        let before = base.expected_time(3, link);
        base.set_exit_probs(&[0.1]);
        assert_eq!(base.exit_probs(), vec![0.1]);
        assert_eq!(fork.exit_probs(), vec![0.1], "fork must see the swap");
        assert_eq!(base.view_epoch(), 1);
        assert_eq!(fork.view_epoch(), 1);
        assert_eq!(base.view_rebuilds(), 1);

        // The swapped view is bit-identical to a fresh planner at p=0.1.
        let (desc01, _) = fixture(0.1);
        let fresh = Planner::new(&desc01, &profile, 1e-9, false);
        for s in 0..=base.num_stages() {
            assert_eq!(
                base.expected_time(s, link).to_bits(),
                fresh.expected_time(s, link).to_bits()
            );
            assert_eq!(
                fork.expected_time(s, link).to_bits(),
                fresh.expected_time(s, link).to_bits()
            );
        }
        assert_ne!(base.expected_time(3, link).to_bits(), before.to_bits());

        // An independent sibling at its own p is untouched.
        let sibling = base.with_exit_probs(&[0.5]);
        base.set_exit_probs(&[0.7]);
        assert_eq!(sibling.exit_probs(), vec![0.5]);
        assert_eq!(sibling.view_epoch(), 0);
    }

    #[test]
    fn view_swap_invalidates_cached_plans() {
        let (desc, profile) = fixture(0.9);
        let planner = Planner::new(&desc, &profile, 1e-9, false);
        // A starved uplink: the optimum is edge-only, whose cost is
        // survival-weighted — so the re-solved plan provably reflects
        // the new p (a cloud-only optimum would cost the same at any p).
        let link = LinkModel::new(0.01, 0.0);

        let p_old = planner.plan_cached(link);
        let _ = planner.plan_cached(link);
        assert_eq!(planner.cache_stats(), (1, 1));
        assert_eq!(planner.cache_invalidations(), 0);

        planner.set_exit_probs(&[0.0]);
        // The previously hit bucket must miss exactly once and re-solve
        // under the new p...
        let p_new = planner.plan_cached(link);
        assert_eq!(planner.cache_stats(), (1, 2));
        assert_eq!(planner.cache_invalidations(), 1);
        assert_eq!(
            p_new,
            planner.plan_for(planner.cache_representative(link)),
            "re-solve must use the new view"
        );
        assert_ne!(
            p_old.expected_time_s.to_bits(),
            p_new.expected_time_s.to_bits()
        );
        // ...then hit again.
        let _ = planner.plan_cached(link);
        assert_eq!(planner.cache_stats(), (2, 2));
    }

    #[test]
    fn serving_mode_adds_branch_cost() {
        let (desc, profile) = fixture(0.5);
        let link = LinkModel::new(5.85, 0.0);
        let paper = Planner::new(&desc, &profile, 1e-9, true);
        let serving = Planner::new(&desc, &profile, 1e-9, false);
        // Branch active only for splits >= 2.
        assert_eq!(
            paper.expected_time(1, link).to_bits(),
            serving.expected_time(1, link).to_bits()
        );
        assert!(serving.expected_time(2, link) > paper.expected_time(2, link));
    }
}
