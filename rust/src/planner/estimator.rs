//! Online exit-rate estimation: the feedback half of the p-parameterized
//! planner core.
//!
//! The paper treats the branch exit probability `p` as a given, but in
//! a deployment it is an *observable*: every sample that reaches the
//! side branch either exits (entropy under the threshold) or survives.
//! The optimal split depends on `p` through Eq. 4's survival product
//! exactly as it depends on bandwidth through `alpha/B` — so a planner
//! frozen at a configured prior keeps executing a split optimized for
//! traffic that isn't arriving. Edge-AI-style runtime co-optimization
//! (Li et al., 1910.05316) couples exit behaviour with partition choice
//! at runtime; this module is that loop's state machine.
//!
//! [`ExitRateEstimator`] is deliberately *pure* (no threads, no clocks):
//! feed it one boolean per branch-gate decision, it maintains an EWMA
//! `p̂` and answers "has the estimate drifted far enough from the p the
//! planner is currently using to justify a view rebuild?". The caller
//! (the fleet's coordinator completion path) then swaps the planner's
//! [`ExitView`](crate::planner::Planner::set_exit_probs) and re-plans —
//! the estimator only decides *when*, which keeps the policy testable
//! without a serving stack.
//!
//! Hysteresis is built in: a rebuild is triggered only after
//! `min_observations` samples (a cold EWMA is noise) and only when
//! `|p̂ − p_planned|` exceeds `drift_threshold`; after a trigger the
//! planned p snaps to `p̂`, so the drift gate re-arms from zero instead
//! of re-firing on every subsequent sample.
//!
//! One structural caveat the caller owns: observations exist only while
//! the executed plan keeps the branch active. If feedback drives the
//! split to or before the branch (cloud-only being the extreme), the
//! gate stops running, the estimator starves, and p̂ freezes at the
//! value that caused the move — a one-way door until something probes
//! the branch again (periodic probe traffic is the planned fix; see
//! ROADMAP).

use anyhow::{bail, Result};

/// Tuning for one class's exit-rate feedback loop.
#[derive(Debug, Clone, Copy)]
pub struct EstimatorConfig {
    /// EWMA weight per observation: `p̂ += alpha · (x − p̂)`. Smaller =
    /// smoother and slower; 0.05 tracks a shift within ~60 samples.
    pub alpha: f64,
    /// Absolute drift `|p̂ − p_planned|` that triggers a view rebuild.
    pub drift_threshold: f64,
    /// Observations required before the first rebuild may fire.
    pub min_observations: u64,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        EstimatorConfig {
            alpha: 0.05,
            drift_threshold: 0.1,
            min_observations: 32,
        }
    }
}

impl EstimatorConfig {
    pub fn validate(&self) -> Result<()> {
        if !(self.alpha > 0.0 && self.alpha <= 1.0) {
            bail!("estimator alpha must be in (0, 1]; got {}", self.alpha);
        }
        if !(self.drift_threshold > 0.0 && self.drift_threshold < 1.0) {
            bail!(
                "estimator drift_threshold must be in (0, 1); got {}",
                self.drift_threshold
            );
        }
        Ok(())
    }
}

/// EWMA exit-rate tracker with a drift gate. One per link class.
#[derive(Debug, Clone)]
pub struct ExitRateEstimator {
    cfg: EstimatorConfig,
    /// Current EWMA estimate of the conditional exit probability.
    p_hat: f64,
    /// The p the planner's live view was last (re)built at.
    planned_p: f64,
    observations: u64,
    rebuilds: u64,
}

impl ExitRateEstimator {
    /// Start from the configured prior (the p the class's planner was
    /// constructed with), so an accurate prior produces zero rebuilds.
    pub fn new(cfg: EstimatorConfig, prior_p: f64) -> ExitRateEstimator {
        cfg.validate().expect("invalid estimator config");
        assert!(
            (0.0..=1.0).contains(&prior_p),
            "prior exit probability {prior_p} not in [0, 1]"
        );
        ExitRateEstimator {
            cfg,
            p_hat: prior_p,
            planned_p: prior_p,
            observations: 0,
            rebuilds: 0,
        }
    }

    /// Record one branch-gate decision (`true` = the sample exited at
    /// the side branch). Returns `Some(p̂)` when the drift gate fires —
    /// the caller should rebuild the planner view at that p; the
    /// estimator has already snapped its planned p to it.
    pub fn observe(&mut self, exited: bool) -> Option<f64> {
        let x = if exited { 1.0 } else { 0.0 };
        self.p_hat += self.cfg.alpha * (x - self.p_hat);
        self.observations += 1;
        if self.observations >= self.cfg.min_observations
            && (self.p_hat - self.planned_p).abs() > self.cfg.drift_threshold
        {
            self.planned_p = self.p_hat;
            self.rebuilds += 1;
            Some(self.p_hat)
        } else {
            None
        }
    }

    /// Current EWMA estimate of the exit probability.
    pub fn p_hat(&self) -> f64 {
        self.p_hat
    }

    /// The p the planner view was last built at (prior until the first
    /// rebuild fires).
    pub fn planned_p(&self) -> f64 {
        self.planned_p
    }

    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// How many times the drift gate has fired.
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds
    }

    pub fn config(&self) -> EstimatorConfig {
        self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(alpha: f64, drift: f64, min_obs: u64) -> EstimatorConfig {
        EstimatorConfig {
            alpha,
            drift_threshold: drift,
            min_observations: min_obs,
        }
    }

    #[test]
    fn accurate_prior_never_rebuilds() {
        // True rate 0.5 alternating, prior 0.5: the EWMA hovers at the
        // prior and the gate stays closed forever.
        let mut e = ExitRateEstimator::new(cfg(0.1, 0.2, 4), 0.5);
        for i in 0..500 {
            assert_eq!(e.observe(i % 2 == 0), None, "obs {i}");
        }
        assert!((e.p_hat() - 0.5).abs() < 0.06, "p̂ = {}", e.p_hat());
        assert_eq!(e.rebuilds(), 0);
        assert_eq!(e.observations(), 500);
    }

    #[test]
    fn drift_fires_once_then_rearms_at_the_new_p() {
        // Prior 0.8, observed rate 0: p̂ decays geometrically; the gate
        // must hold until min_observations, fire, snap planned_p to p̂,
        // and not re-fire until the estimate moves another full
        // threshold away.
        let mut e = ExitRateEstimator::new(cfg(0.2, 0.3, 8), 0.8);
        let mut fired_at = Vec::new();
        for i in 0..40 {
            if let Some(p) = e.observe(false) {
                fired_at.push((i, p));
            }
        }
        assert!(!fired_at.is_empty(), "gate never fired");
        // 0.8·0.8^k drops below 0.5 at k=3, but min_observations holds
        // the gate until observation index 7 (the 8th sample).
        assert_eq!(fired_at[0].0, 7, "{fired_at:?}");
        assert!(fired_at[0].1 < 0.5);
        // Each subsequent firing is a further full threshold below the
        // previous planned p — geometric decay toward 0 can cross 0.3
        // at most once more from p̂ ≈ 0.13.
        assert!(fired_at.len() <= 2, "{fired_at:?}");
        assert_eq!(e.rebuilds() as usize, fired_at.len());
        assert_eq!(e.planned_p(), fired_at.last().unwrap().1);
        assert!(e.p_hat() < 0.05, "p̂ should approach 0: {}", e.p_hat());
    }

    #[test]
    fn upward_drift_converges_toward_observed_rate() {
        let mut e = ExitRateEstimator::new(cfg(0.1, 0.15, 16), 0.1);
        let mut rebuild_ps = Vec::new();
        for _ in 0..200 {
            if let Some(p) = e.observe(true) {
                rebuild_ps.push(p);
            }
        }
        assert!(e.p_hat() > 0.95, "p̂ = {}", e.p_hat());
        assert!(e.rebuilds() >= 2, "expected staged rebuilds upward");
        assert!(
            rebuild_ps.windows(2).all(|w| w[1] > w[0]),
            "rebuild sequence must be monotone upward: {rebuild_ps:?}"
        );
        assert!((e.planned_p() - e.p_hat()).abs() <= 0.15 + 1e-12);
    }

    #[test]
    fn config_validation() {
        assert!(cfg(0.0, 0.1, 1).validate().is_err());
        assert!(cfg(1.5, 0.1, 1).validate().is_err());
        assert!(cfg(0.1, 0.0, 1).validate().is_err());
        assert!(cfg(0.1, 1.0, 1).validate().is_err());
        assert!(cfg(1.0, 0.99, 0).validate().is_ok());
        EstimatorConfig::default().validate().unwrap();
    }
}
