//! Adaptive replanning: the "re-solve on every bandwidth sample" loop,
//! promoted out of `examples/adaptive_bandwidth.rs` into the subsystem.
//!
//! Split in two so the decision logic is testable without threads or
//! artifacts:
//!
//! * [`ReplanState`] — a pure state machine: feed it link observations,
//!   it returns `Some(plan)` when the active plan should change. It
//!   plans through the [`Planner`]'s bucket cache and applies
//!   hysteresis: a new split is adopted only if its predicted expected
//!   time beats the current split's (at the *observed* link) by a
//!   configurable relative margin, and a minimum dwell time has passed
//!   since the last switch — so the split doesn't flap between
//!   adjacent buckets when the uplink hovers at a decision boundary.
//! * [`AdaptivePlanner`] — the thread wrapper: polls a link source
//!   (e.g. the coordinator's [`crate::network::Channel`]) on an
//!   interval and pushes accepted plans into a sink (e.g.
//!   [`Coordinator::set_plan`], which counts plan switches in
//!   `coordinator::metrics`).
//!
//! Degenerate bandwidth samples (a measured 0 Mbps, NaN from a broken
//! estimator) cannot kill the loop: `LinkModel::new` clamps to a
//! documented floor instead of panicking.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::Coordinator;
use crate::network::bandwidth::LinkModel;
use crate::partition::plan::PartitionPlan;

use super::Planner;

#[derive(Debug, Clone, Copy)]
pub struct AdaptiveConfig {
    /// How often the link source is polled.
    pub interval: Duration,
    /// Hysteresis: relative `E[T]` improvement the candidate split must
    /// offer over the current one before a switch happens.
    pub min_improvement: f64,
    /// Hysteresis: minimum time between two plan switches.
    pub min_dwell: Duration,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            interval: Duration::from_millis(500),
            min_improvement: 0.02,
            min_dwell: Duration::from_millis(500),
        }
    }
}

/// Counters reported by the replan loop.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplanStats {
    /// Link observations evaluated.
    pub replans: u64,
    /// Plan switches actually emitted.
    pub switches: u64,
    /// Plan-cache hits / misses (from the planner's [`super::PlanCache`]).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Pure replanning state machine. Time is passed in explicitly
/// (seconds since an arbitrary epoch) so tests don't need a clock.
#[derive(Debug)]
pub struct ReplanState {
    planner: Planner,
    cfg: AdaptiveConfig,
    current_split: Option<usize>,
    last_switch_s: f64,
    replans: u64,
    switches: u64,
}

impl ReplanState {
    pub fn new(planner: Planner, cfg: AdaptiveConfig) -> ReplanState {
        Self::with_initial_split(planner, cfg, None)
    }

    /// Seed with the split that is already active (e.g. the plan the
    /// coordinator was started with), so the first observation only
    /// counts as a switch if it actually moves the split — keeping
    /// [`ReplanStats::switches`] in agreement with the coordinator's
    /// `metrics.plan_switches`.
    pub fn with_initial_split(
        planner: Planner,
        cfg: AdaptiveConfig,
        current_split: Option<usize>,
    ) -> ReplanState {
        ReplanState {
            planner,
            cfg,
            current_split,
            last_switch_s: f64::NEG_INFINITY,
            replans: 0,
            switches: 0,
        }
    }

    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    pub fn current_split(&self) -> Option<usize> {
        self.current_split
    }

    /// Evaluate one bandwidth observation. Returns the plan to apply
    /// when the hysteresis test says the split should move.
    pub fn observe(&mut self, link: LinkModel, now_s: f64) -> Option<PartitionPlan> {
        self.replans += 1;
        let candidate = self.planner.plan_cached(link);
        let switch = match self.current_split {
            None => true,
            Some(cur) if cur == candidate.split_after => false,
            Some(cur) => {
                // Compare both splits at the *observed* link, not the
                // bucket representative the cached plan was solved at.
                let cur_cost = self.planner.expected_time(cur, link);
                let new_cost = self.planner.expected_time(candidate.split_after, link);
                let dwell_ok =
                    now_s - self.last_switch_s >= self.cfg.min_dwell.as_secs_f64();
                dwell_ok
                    && cur_cost.is_finite()
                    && cur_cost > 0.0
                    && (cur_cost - new_cost) >= self.cfg.min_improvement * cur_cost
            }
        };
        if switch {
            self.current_split = Some(candidate.split_after);
            self.last_switch_s = now_s;
            self.switches += 1;
            Some(candidate)
        } else {
            None
        }
    }

    pub fn stats(&self) -> ReplanStats {
        let (cache_hits, cache_misses) = self.planner.cache_stats();
        ReplanStats {
            replans: self.replans,
            switches: self.switches,
            cache_hits,
            cache_misses,
        }
    }
}

/// Handle to a running replan thread. [`AdaptiveHandle::stop`] joins it
/// and returns the loop's counters.
pub struct AdaptiveHandle {
    stop: Arc<AtomicBool>,
    handle: JoinHandle<ReplanStats>,
}

impl AdaptiveHandle {
    pub fn stop(self) -> ReplanStats {
        self.stop.store(true, Ordering::Relaxed);
        match self.handle.join() {
            Ok(stats) => stats,
            Err(_) => {
                // A panicked loop means replanning silently stopped at
                // some point — say so instead of returning zeros as if
                // the loop ran cleanly.
                log::error!("replanner thread panicked; its stats are lost");
                ReplanStats::default()
            }
        }
    }
}

/// The background replan loop.
pub struct AdaptivePlanner;

impl AdaptivePlanner {
    /// Poll the coordinator's channel and swap its plan live. In-flight
    /// batches finish under the old plan (see `Coordinator::set_plan`);
    /// the coordinator's metrics count the switches.
    pub fn spawn(
        planner: Planner,
        coordinator: Arc<Coordinator>,
        cfg: AdaptiveConfig,
    ) -> AdaptiveHandle {
        let initial_split = Some(coordinator.plan().split_after);
        let source = {
            let coordinator = coordinator.clone();
            move || coordinator.channel().current_link()
        };
        let sink = move |plan: PartitionPlan| coordinator.set_plan(plan);
        Self::spawn_with(planner, cfg, initial_split, source, sink)
    }

    /// Generic variant: any link source and plan sink. Used by the
    /// coordinator wrapper above and directly by tests/benches.
    /// `initial_split` is the split already active at the sink, if any.
    pub fn spawn_with(
        planner: Planner,
        cfg: AdaptiveConfig,
        initial_split: Option<usize>,
        mut source: impl FnMut() -> LinkModel + Send + 'static,
        mut sink: impl FnMut(PartitionPlan) + Send + 'static,
    ) -> AdaptiveHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("replanner".into())
            .spawn(move || {
                let mut state = ReplanState::with_initial_split(planner, cfg, initial_split);
                let t0 = Instant::now();
                while !stop2.load(Ordering::Relaxed) {
                    let link = source();
                    if let Some(plan) = state.observe(link, t0.elapsed().as_secs_f64()) {
                        log::info!(
                            "[replan] {:.2} Mbps -> split after {} (E[T] {:.4}s)",
                            link.uplink_mbps,
                            plan.split_after,
                            plan.expected_time_s
                        );
                        sink(plan);
                    }
                    // Sleep in short slices so stop() returns promptly.
                    let mut slept = Duration::ZERO;
                    while slept < cfg.interval && !stop2.load(Ordering::Relaxed) {
                        let step = (cfg.interval - slept).min(Duration::from_millis(50));
                        std::thread::sleep(step);
                        slept += step;
                    }
                }
                state.stats()
            })
            .expect("spawn replanner thread");
        AdaptiveHandle { stop, handle }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BranchDesc, BranchyNetDesc};
    use crate::timing::DelayProfile;

    /// Fixture where 1 Mbps prefers the edge and a very fast uplink
    /// prefers cloud-only.
    fn planner() -> Planner {
        let desc = BranchyNetDesc {
            stage_names: (1..=5).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![57_600, 18_816, 25_088, 3_456, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.5,
            }],
        };
        let profile = DelayProfile::from_cloud_times(
            vec![1e-4, 2e-4, 1.5e-4, 8e-5, 2e-5],
            3e-5,
            100.0,
        );
        Planner::new(&desc, &profile, 1e-9, false)
    }

    fn cfg() -> AdaptiveConfig {
        AdaptiveConfig {
            interval: Duration::from_millis(1),
            min_improvement: 0.02,
            min_dwell: Duration::ZERO,
        }
    }

    #[test]
    fn first_observation_always_sets_a_plan() {
        let mut st = ReplanState::new(planner(), cfg());
        let p = st.observe(LinkModel::new(1.0, 0.0), 0.0);
        assert!(p.is_some());
        assert_eq!(st.current_split(), Some(p.unwrap().split_after));
        assert_eq!(st.stats().switches, 1);
    }

    #[test]
    fn seeded_initial_split_counts_no_spurious_switch() {
        // Seeded with the split that is already active, an observation
        // agreeing with it must not count as a switch — so the loop's
        // counter matches the coordinator's metrics.plan_switches.
        let p = planner();
        let active = p.plan_for(LinkModel::new(1.0, 0.0)).split_after;
        let mut st = ReplanState::with_initial_split(p, cfg(), Some(active));
        assert!(st.observe(LinkModel::new(1.0, 0.0), 0.0).is_none());
        assert_eq!(st.stats().switches, 0);
    }

    #[test]
    fn small_jitter_within_a_bucket_does_not_flap() {
        let mut st = ReplanState::new(planner(), cfg());
        st.observe(LinkModel::new(1.0, 0.0), 0.0).unwrap();
        // ±1% jitter stays in the same log bucket -> same cached plan.
        for (i, mbps) in [1.01, 0.99, 1.005, 1.0].iter().enumerate() {
            assert!(
                st.observe(LinkModel::new(*mbps, 0.0), 1.0 + i as f64).is_none(),
                "{mbps} Mbps should not flap the plan"
            );
        }
        let s = st.stats();
        assert_eq!(s.switches, 1);
        assert_eq!(s.replans, 5);
        assert!(s.cache_hits >= 3, "jitter should hit the cache: {s:?}");
    }

    #[test]
    fn large_swing_switches_and_counts() {
        let mut st = ReplanState::new(planner(), cfg());
        let p1 = st.observe(LinkModel::new(1.0, 0.0), 0.0).unwrap();
        let p2 = st.observe(LinkModel::new(50_000.0, 0.0), 1.0).unwrap();
        assert_ne!(p1.split_after, p2.split_after);
        assert!(p2.is_cloud_only(), "{p2:?}");
        assert_eq!(st.stats().switches, 2);
    }

    #[test]
    fn dwell_time_suppresses_rapid_switches() {
        let mut c = cfg();
        c.min_dwell = Duration::from_secs(10);
        let mut st = ReplanState::new(planner(), c);
        st.observe(LinkModel::new(1.0, 0.0), 0.0).unwrap();
        // A genuinely better plan exists, but the dwell gate holds it.
        assert!(st.observe(LinkModel::new(50_000.0, 0.0), 1.0).is_none());
        // After the dwell expires it goes through.
        assert!(st.observe(LinkModel::new(50_000.0, 0.0), 11.0).is_some());
    }

    #[test]
    fn degenerate_bandwidth_does_not_panic() {
        let mut st = ReplanState::new(planner(), cfg());
        // A dead uplink sample: clamped by LinkModel, loop survives.
        let p = st.observe(LinkModel::new(0.0, 0.0), 0.0);
        assert!(p.is_some());
        assert!(st.observe(LinkModel::new(f64::NAN, 0.0), 1.0).is_none());
    }

    #[test]
    fn spawn_with_drives_sink_and_stops() {
        use std::sync::Mutex;
        let applied: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        let applied2 = applied.clone();
        let handle = AdaptivePlanner::spawn_with(
            planner(),
            cfg(),
            None,
            || LinkModel::new(1.0, 0.0),
            move |plan| applied2.lock().unwrap().push(plan.split_after),
        );
        // Give the loop a few ticks.
        std::thread::sleep(Duration::from_millis(30));
        let stats = handle.stop();
        assert!(stats.replans >= 1);
        assert_eq!(stats.switches, 1, "constant link must switch exactly once");
        assert_eq!(applied.lock().unwrap().len(), 1);
    }
}
