//! Plan cache keyed by quantized link state.
//!
//! Bandwidth is quantized on a log grid (`buckets_per_decade` buckets
//! per factor-of-10, default 24 ≈ 10% per bucket) and the RTT at 1 µs
//! resolution. All links mapping to the same key share one plan,
//! computed at the bucket's *representative* bandwidth — deterministic
//! regardless of which sample arrived first. Log bucketing matches the
//! model's sensitivity: `E[T]` depends on bandwidth only through
//! `alpha/B`, so a fixed *relative* quantization bounds the relative
//! cost error of a cached plan by the bucket width.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::network::bandwidth::LinkModel;
use crate::partition::plan::PartitionPlan;

/// Default log-bucket resolution: 24 buckets per decade, i.e. adjacent
/// buckets differ by 10^(1/24) ≈ 1.10 in bandwidth (and in RTT).
pub const DEFAULT_BUCKETS_PER_DECADE: u32 = 24;

/// Size bound: the map is cleared (counted in `evictions`) when it
/// would exceed this many plans. With ~24 buckets/decade the whole
/// plausible (bandwidth × RTT) plane is a few hundred buckets, so the
/// bound only trips for pathological link sources.
pub const MAX_CACHED_PLANS: usize = 4096;

/// RTTs below this (including the common exact 0) share one sentinel
/// bucket instead of feeding `log10` a zero.
const MIN_RTT_S: f64 = 1e-6;

/// Cache key: log-bucketed Mbps × log-bucketed RTT. RTT gets the same
/// *relative* quantization as bandwidth — keying it at fixed absolute
/// resolution would make every jittering RTT sample a distinct miss.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub bw_bucket: i64,
    pub rtt_bucket: i64,
}

/// Thread-safe memo of plans by quantized link, with hit/miss counters.
#[derive(Debug)]
pub struct PlanCache {
    buckets_per_decade: f64,
    map: Mutex<HashMap<CacheKey, PartitionPlan>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new(DEFAULT_BUCKETS_PER_DECADE)
    }
}

impl PlanCache {
    pub fn new(buckets_per_decade: u32) -> PlanCache {
        assert!(buckets_per_decade >= 1);
        PlanCache {
            buckets_per_decade: buckets_per_decade as f64,
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Quantize a link. `LinkModel` guarantees a positive finite
    /// bandwidth (it clamps at construction), so the log is finite.
    /// RTTs under [`MIN_RTT_S`] share one sentinel bucket.
    pub fn key_for(&self, link: LinkModel) -> CacheKey {
        let rtt_bucket = if link.rtt_s < MIN_RTT_S {
            i64::MIN
        } else {
            (link.rtt_s.log10() * self.buckets_per_decade).round() as i64
        };
        CacheKey {
            bw_bucket: (link.uplink_mbps.log10() * self.buckets_per_decade).round() as i64,
            rtt_bucket,
        }
    }

    /// The canonical link a key stands for (bucket center).
    pub fn representative(&self, key: CacheKey) -> LinkModel {
        let rtt_s = if key.rtt_bucket == i64::MIN {
            0.0
        } else {
            10f64.powf(key.rtt_bucket as f64 / self.buckets_per_decade)
        };
        LinkModel::new(
            10f64.powf(key.bw_bucket as f64 / self.buckets_per_decade),
            rtt_s,
        )
    }

    /// Look up the plan for `link`'s bucket, computing it at the bucket
    /// representative on a miss.
    pub fn get_or_insert_with(
        &self,
        link: LinkModel,
        compute: impl FnOnce(LinkModel) -> PartitionPlan,
    ) -> PartitionPlan {
        let key = self.key_for(link);
        if let Some(plan) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return plan.clone();
        }
        let plan = compute(self.representative(key));
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().unwrap();
        if map.len() >= MAX_CACHED_PLANS && !map.contains_key(&key) {
            // Pathological link source filled the plane: start over
            // rather than grow without bound.
            map.clear();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        map.entry(key).or_insert(plan).clone()
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// How many times the size bound flushed the whole map.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.lock().unwrap().is_empty()
    }

    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::Strategy;
    use crate::model::BranchyNetDesc;

    fn dummy_plan(split: usize) -> PartitionPlan {
        let desc = BranchyNetDesc {
            stage_names: vec!["a".into(), "b".into(), "c".into()],
            stage_out_bytes: vec![10, 10, 10],
            input_bytes: 10,
            branches: vec![],
        };
        PartitionPlan::from_split(split, 0.1, Strategy::ShortestPath, &desc)
    }

    #[test]
    fn nearby_bandwidths_share_a_bucket() {
        let c = PlanCache::default();
        let k1 = c.key_for(LinkModel::new(5.85, 0.0));
        let k2 = c.key_for(LinkModel::new(5.87, 0.0));
        assert_eq!(k1, k2);
        // The paper's three profiles land in distinct buckets.
        let k3g = c.key_for(LinkModel::new(1.10, 0.0));
        let k4g = c.key_for(LinkModel::new(5.85, 0.0));
        let kwifi = c.key_for(LinkModel::new(18.80, 0.0));
        assert!(k3g != k4g && k4g != kwifi);
        // RTT participates in the key.
        assert_ne!(
            c.key_for(LinkModel::new(5.85, 0.01)),
            c.key_for(LinkModel::new(5.85, 0.02))
        );
    }

    #[test]
    fn representative_is_inside_its_own_bucket() {
        let c = PlanCache::default();
        for mbps in [0.01, 0.5, 1.1, 5.85, 18.8, 100.0, 2500.0] {
            let key = c.key_for(LinkModel::new(mbps, 0.003));
            let rep = c.representative(key);
            assert_eq!(c.key_for(rep), key, "mbps={mbps}");
            // Representative within one bucket width of the sample.
            let ratio = rep.uplink_mbps / mbps;
            assert!((0.9..=1.12).contains(&ratio), "mbps={mbps} rep={ratio}");
        }
    }

    #[test]
    fn hit_and_miss_accounting() {
        let c = PlanCache::default();
        let l = LinkModel::new(5.85, 0.0);
        let p1 = c.get_or_insert_with(l, |_| dummy_plan(1));
        assert_eq!(c.stats(), (0, 1));
        // Hit returns the cached plan, even if compute would differ now.
        let p2 = c.get_or_insert_with(l, |_| dummy_plan(2));
        assert_eq!(c.stats(), (1, 1));
        assert_eq!(p1, p2);
        assert_eq!(c.len(), 1);
        c.clear();
        assert!(c.is_empty());
    }
}
