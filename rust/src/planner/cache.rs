//! Plan cache keyed by quantized link state, invalidated by view epoch.
//!
//! Bandwidth is quantized on a log grid (`buckets_per_decade` buckets
//! per factor-of-10, default 24 ≈ 10% per bucket) and the RTT at 1 µs
//! resolution. All links mapping to the same key share one plan,
//! computed at the bucket's *representative* bandwidth — deterministic
//! regardless of which sample arrived first. Log bucketing matches the
//! model's sensitivity: `E[T]` depends on bandwidth only through
//! `alpha/B`, so a fixed *relative* quantization bounds the relative
//! cost error of a cached plan by the bucket width.
//!
//! Cached plans are only valid for the exit-probability view they were
//! solved under, so the cache carries the **view epoch** it last saw:
//! [`PlanCache::get_or_insert_at_epoch`] flushes the whole map the
//! first time it observes a new epoch (counted in `invalidations`), so
//! after a `Planner::set_exit_probs` every bucket misses exactly once
//! and re-solves under the new p — no stale plan can survive a
//! p-update.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::network::bandwidth::LinkModel;
use crate::partition::plan::PartitionPlan;

/// Default log-bucket resolution: 24 buckets per decade, i.e. adjacent
/// buckets differ by 10^(1/24) ≈ 1.10 in bandwidth (and in RTT).
pub const DEFAULT_BUCKETS_PER_DECADE: u32 = 24;

/// Size bound: the map is cleared (counted in `evictions`) when it
/// would exceed this many plans. With ~24 buckets/decade the whole
/// plausible (bandwidth × RTT) plane is a few hundred buckets, so the
/// bound only trips for pathological link sources.
pub const MAX_CACHED_PLANS: usize = 4096;

/// RTTs below this (including the common exact 0) share one sentinel
/// bucket instead of feeding `log10` a zero.
const MIN_RTT_S: f64 = 1e-6;

/// Cache key: log-bucketed Mbps × log-bucketed RTT. RTT gets the same
/// *relative* quantization as bandwidth — keying it at fixed absolute
/// resolution would make every jittering RTT sample a distinct miss.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub bw_bucket: i64,
    pub rtt_bucket: i64,
}

/// Thread-safe memo of plans by quantized link, with hit/miss counters
/// and whole-map invalidation on view-epoch changes.
#[derive(Debug)]
pub struct PlanCache {
    buckets_per_decade: f64,
    map: Mutex<HashMap<CacheKey, PartitionPlan>>,
    /// The view epoch the cached plans were solved under. Only mutated
    /// while holding the map lock, so epoch and contents stay coherent.
    epoch: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    invalidations: AtomicU64,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new(DEFAULT_BUCKETS_PER_DECADE)
    }
}

impl PlanCache {
    pub fn new(buckets_per_decade: u32) -> PlanCache {
        assert!(buckets_per_decade >= 1);
        PlanCache {
            buckets_per_decade: buckets_per_decade as f64,
            map: Mutex::new(HashMap::new()),
            epoch: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
        }
    }

    /// Align a *fresh, empty* cache with an already-advanced view epoch
    /// (e.g. a `Planner::fork` taken after p-updates) without counting
    /// a spurious invalidation on first use.
    pub fn seed_epoch(&self, epoch: u64) {
        let map = self.map.lock().unwrap();
        debug_assert!(map.is_empty(), "seed_epoch is for empty caches");
        drop(map);
        self.epoch.store(epoch, Ordering::Relaxed);
    }

    /// Quantize a link. `LinkModel` guarantees a positive finite
    /// bandwidth (it clamps at construction), so the log is finite.
    /// RTTs under `MIN_RTT_S` (1 µs) share one sentinel bucket.
    pub fn key_for(&self, link: LinkModel) -> CacheKey {
        let rtt_bucket = if link.rtt_s < MIN_RTT_S {
            i64::MIN
        } else {
            (link.rtt_s.log10() * self.buckets_per_decade).round() as i64
        };
        CacheKey {
            bw_bucket: (link.uplink_mbps.log10() * self.buckets_per_decade).round() as i64,
            rtt_bucket,
        }
    }

    /// The canonical link a key stands for (bucket center).
    pub fn representative(&self, key: CacheKey) -> LinkModel {
        let rtt_s = if key.rtt_bucket == i64::MIN {
            0.0
        } else {
            10f64.powf(key.rtt_bucket as f64 / self.buckets_per_decade)
        };
        LinkModel::new(
            10f64.powf(key.bw_bucket as f64 / self.buckets_per_decade),
            rtt_s,
        )
    }

    /// Look up the plan for `link`'s bucket at the cache's current view
    /// epoch, computing it at the bucket representative on a miss.
    pub fn get_or_insert_with(
        &self,
        link: LinkModel,
        compute: impl FnOnce(LinkModel) -> PartitionPlan,
    ) -> PartitionPlan {
        self.get_or_insert_at_epoch(link, self.epoch.load(Ordering::Relaxed), compute)
    }

    /// Epoch-checked lookup: if `epoch` is *newer* than the one the
    /// cached plans were solved under, the whole map is flushed first
    /// (counted in `invalidations`) — so every bucket misses exactly
    /// once after a view swap and re-solves via `compute` under the new
    /// view. Epochs are monotonic: a caller holding an older epoch (it
    /// loaded the counter just before a concurrent swap) neither
    /// flushes the freshly repopulated map nor rolls the stored epoch
    /// backwards — the live view is the newer one, so serving or
    /// computing under it is correct; the straggler just never inserts.
    pub fn get_or_insert_at_epoch(
        &self,
        link: LinkModel,
        epoch: u64,
        compute: impl FnOnce(LinkModel) -> PartitionPlan,
    ) -> PartitionPlan {
        let key = self.key_for(link);
        {
            let mut map = self.map.lock().unwrap();
            if epoch > self.epoch.load(Ordering::Relaxed) {
                map.clear();
                self.epoch.store(epoch, Ordering::Relaxed);
                self.invalidations.fetch_add(1, Ordering::Relaxed);
            }
            if let Some(plan) = map.get(&key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return plan.clone();
            }
        }
        let plan = compute(self.representative(key));
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().unwrap();
        if self.epoch.load(Ordering::Relaxed) != epoch {
            // The view moved while we were solving (or we were already
            // behind it): hand the plan out once but don't poison the
            // map the current epoch owns.
            return plan;
        }
        if map.len() >= MAX_CACHED_PLANS && !map.contains_key(&key) {
            // Pathological link source filled the plane: start over
            // rather than grow without bound.
            map.clear();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        map.entry(key).or_insert(plan).clone()
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// How many times the size bound flushed the whole map.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// How many times a view-epoch change flushed the whole map.
    pub fn invalidations(&self) -> u64 {
        self.invalidations.load(Ordering::Relaxed)
    }

    /// The view epoch the cached plans were solved under.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.lock().unwrap().is_empty()
    }

    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::Strategy;
    use crate::model::BranchyNetDesc;

    fn dummy_plan(split: usize) -> PartitionPlan {
        let desc = BranchyNetDesc {
            stage_names: vec!["a".into(), "b".into(), "c".into()],
            stage_out_bytes: vec![10, 10, 10],
            input_bytes: 10,
            branches: vec![],
        };
        PartitionPlan::from_split(split, 0.1, Strategy::ShortestPath, &desc)
    }

    #[test]
    fn nearby_bandwidths_share_a_bucket() {
        let c = PlanCache::default();
        let k1 = c.key_for(LinkModel::new(5.85, 0.0));
        let k2 = c.key_for(LinkModel::new(5.87, 0.0));
        assert_eq!(k1, k2);
        // The paper's three profiles land in distinct buckets.
        let k3g = c.key_for(LinkModel::new(1.10, 0.0));
        let k4g = c.key_for(LinkModel::new(5.85, 0.0));
        let kwifi = c.key_for(LinkModel::new(18.80, 0.0));
        assert!(k3g != k4g && k4g != kwifi);
        // RTT participates in the key.
        assert_ne!(
            c.key_for(LinkModel::new(5.85, 0.01)),
            c.key_for(LinkModel::new(5.85, 0.02))
        );
    }

    #[test]
    fn representative_is_inside_its_own_bucket() {
        let c = PlanCache::default();
        for mbps in [0.01, 0.5, 1.1, 5.85, 18.8, 100.0, 2500.0] {
            let key = c.key_for(LinkModel::new(mbps, 0.003));
            let rep = c.representative(key);
            assert_eq!(c.key_for(rep), key, "mbps={mbps}");
            // Representative within one bucket width of the sample.
            let ratio = rep.uplink_mbps / mbps;
            assert!((0.9..=1.12).contains(&ratio), "mbps={mbps} rep={ratio}");
        }
    }

    #[test]
    fn hit_and_miss_accounting() {
        let c = PlanCache::default();
        let l = LinkModel::new(5.85, 0.0);
        let p1 = c.get_or_insert_with(l, |_| dummy_plan(1));
        assert_eq!(c.stats(), (0, 1));
        // Hit returns the cached plan, even if compute would differ now.
        let p2 = c.get_or_insert_with(l, |_| dummy_plan(2));
        assert_eq!(c.stats(), (1, 1));
        assert_eq!(p1, p2);
        assert_eq!(c.len(), 1);
        c.clear();
        assert!(c.is_empty());
    }

    #[test]
    fn epoch_change_invalidates_then_resolves_once() {
        let c = PlanCache::default();
        let l = LinkModel::new(5.85, 0.0);
        let other = LinkModel::new(58.5, 0.0);

        // Two buckets populated and hit under epoch 0.
        let _ = c.get_or_insert_at_epoch(l, 0, |_| dummy_plan(1));
        let _ = c.get_or_insert_at_epoch(other, 0, |_| dummy_plan(2));
        let hit = c.get_or_insert_at_epoch(l, 0, |_| dummy_plan(9));
        assert_eq!(hit, dummy_plan(1));
        assert_eq!(c.stats(), (1, 2));
        assert_eq!(c.len(), 2);
        assert_eq!((c.epoch(), c.invalidations()), (0, 0));

        // New epoch: the previously hit bucket must miss exactly once
        // and re-solve (the compute result under the "new p" wins)...
        let resolved = c.get_or_insert_at_epoch(l, 1, |_| dummy_plan(3));
        assert_eq!(resolved, dummy_plan(3), "stale plan served after swap");
        assert_eq!(c.stats(), (1, 3));
        assert_eq!((c.epoch(), c.invalidations()), (1, 1));
        // ...and the flush is whole-map: the other bucket re-solves too.
        let resolved2 = c.get_or_insert_at_epoch(other, 1, |_| dummy_plan(4));
        assert_eq!(resolved2, dummy_plan(4));
        assert_eq!(c.stats(), (1, 4));
        assert_eq!(c.invalidations(), 1, "one swap = one flush");

        // Steady state at the new epoch: hits again.
        let hit2 = c.get_or_insert_at_epoch(l, 1, |_| dummy_plan(9));
        assert_eq!(hit2, dummy_plan(3));
        assert_eq!(c.stats(), (2, 4));
    }

    #[test]
    fn seeded_epoch_does_not_count_an_invalidation() {
        let c = PlanCache::default();
        c.seed_epoch(7);
        let l = LinkModel::new(5.85, 0.0);
        let _ = c.get_or_insert_at_epoch(l, 7, |_| dummy_plan(1));
        let _ = c.get_or_insert_at_epoch(l, 7, |_| dummy_plan(2));
        assert_eq!(c.stats(), (1, 1));
        assert_eq!(c.invalidations(), 0);
    }

    #[test]
    fn stale_epoch_caller_does_not_flush_or_roll_back() {
        // A straggler that loaded the epoch counter just before a swap
        // must not wipe the freshly repopulated cache or move the
        // stored epoch backwards (epochs are monotonic).
        let c = PlanCache::default();
        let l = LinkModel::new(5.85, 0.0);
        let _ = c.get_or_insert_at_epoch(l, 1, |_| dummy_plan(1)); // current epoch 1
        assert_eq!((c.epoch(), c.len()), (1, 1));

        // Straggler at epoch 0: no flush, no rollback, serves the live
        // entry (the live view is the newer one).
        let got = c.get_or_insert_at_epoch(l, 0, |_| dummy_plan(9));
        assert_eq!(got, dummy_plan(1));
        assert_eq!((c.epoch(), c.len()), (1, 1));
        assert_eq!(c.invalidations(), 1, "only the 0->1 advance counts");

        // Straggler missing on an uncached bucket computes but does not
        // insert under the current epoch.
        let other = LinkModel::new(58.5, 0.0);
        let got = c.get_or_insert_at_epoch(other, 0, |_| dummy_plan(2));
        assert_eq!(got, dummy_plan(2));
        assert_eq!(c.len(), 1, "stale compute must not populate the map");
        // The current-epoch caller re-solves it for real.
        let got = c.get_or_insert_at_epoch(other, 1, |_| dummy_plan(3));
        assert_eq!(got, dummy_plan(3));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn racing_epoch_bump_does_not_poison_the_map() {
        // A compute that finishes after the epoch has already moved on
        // must not be inserted under the new epoch.
        let c = PlanCache::default();
        let l = LinkModel::new(5.85, 0.0);
        let stale = c.get_or_insert_at_epoch(l, 0, |_| {
            // Simulate a concurrent swap landing mid-compute.
            c.seed_epoch(1);
            dummy_plan(1)
        });
        assert_eq!(stale, dummy_plan(1), "caller still gets its plan once");
        // The stale plan was not cached: the next query re-solves.
        let fresh = c.get_or_insert_at_epoch(l, 1, |_| dummy_plan(2));
        assert_eq!(fresh, dummy_plan(2));
    }
}
