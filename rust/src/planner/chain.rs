//! K-tier partition chains: the cut *vector* generalization.
//!
//! The paper's device/cloud split is the K = 2 instance of a general
//! chain partition: K tiers (edge, any number of intermediate tiers,
//! a terminal tier) connected by K−1 links, with a monotone cut vector
//! `cuts[0] <= cuts[1] <= … <= cuts[K-2]` assigning stages
//! `1..=cuts[0]` to the edge, `cuts[k-1]+1..=cuts[k]` to tier `k`, and
//! `cuts[K-2]+1..=N` to the terminal tier. The shortest-path
//! equivalence the planner collapses into a sweep survives intact: the
//! layered graph simply gains one layer per tier, and because early
//! exits only ever fire on the edge (branch gates run before the first
//! cut; downstream tiers never gate), the survival weight factors out
//! of everything past hop 0:
//!
//! ```text
//! E[T(cuts)] = A(c0) + S(c0) · ( hop0(c0) + R1(c0) )
//!
//! Rk(i)      = scale_k · (C(i) − C(j))                    j = cuts[k]
//!            + [j < N] · ( hopk(j) + Rk+1(j) )            (k < K−1)
//! RK-1(i)    = scale_K-1 · (C(i) − C(N))
//! ```
//!
//! with `A`, `S`, `C` exactly the planner's prefix/suffix tables and
//! `hopk(j)` the k-th link's transfer time for the wire-encoded
//! activation at stage `j`. [`Planner::plan_chain`] solves the argmin
//! over all monotone cut vectors as a layered dynamic program in
//! O(K·N²): one table `R_k` per intermediate tier, each entry a 1-D
//! minimization over the next cut, then the familiar O(N) epsilon
//! sweep over the edge cut. With K = 2 the single table is
//! `1.0 · (C(i) − 0.0)` — bit-identical to `C(i)` — so `plan_chain`
//! over [`TierChain::two_tier`] collapses **bit-identically** to
//! [`Planner::plan_for`] (property-tested in
//! `rust/tests/planner_equivalence.rs`; the exhaustive cut-vector
//! oracle lives in `rust/tests/ktier_optimality.rs`).
//!
//! Tie-breaking follows the paper's epsilon rule, generalized: the
//! decision value carries `+epsilon` exactly when `cuts[0] < N` (the
//! vector transfers *something*), and every minimization scans
//! ascending with `<=` — so exact ties resolve toward the
//! lexicographically **largest** cut vector, i.e. toward keeping work
//! on the earliest possible tier, the same direction `plan_for`
//! resolves its single cut. When the edge cut kills all survival
//! (`S(c0) = 0`, the p = 1 corner) or runs the whole net
//! (`cuts[0] = N`), nothing ever crosses hop 0 and every downstream
//! cut is reported as `N` — the lexicographically largest of the
//! all-tied tails.

use crate::network::bandwidth::LinkModel;

use super::{Planner, StaticCore};

/// A K-tier deployment topology as the planner prices it: K−1 links and
/// K−1 compute scales, describing the tiers *beyond* the edge. Tier 0
/// (the edge) contributes the planner's own profiled `t_edge`; tier `k`
/// (1-based) runs its stages at `compute_scale[k-1] ×` the profiled
/// cloud time and receives its input over `links[k-1]`.
#[derive(Debug, Clone, PartialEq)]
pub struct TierChain {
    /// `links[h]` is the hop from tier `h` to tier `h+1`; `links[0]` is
    /// the edge's own uplink. K−1 entries for a K-tier chain.
    pub links: Vec<LinkModel>,
    /// Per-tier compute time relative to the profiled cloud, one entry
    /// per tier beyond the edge (the last entry is the terminal tier).
    /// `1.0` = exactly the profile's `t_cloud`; `0.0` (a free
    /// pass-through relay) is allowed.
    pub compute_scale: Vec<f64>,
}

impl TierChain {
    /// The paper's topology: one hop to a cloud running the profiled
    /// `t_cloud` unscaled. [`Planner::plan_chain`] over this chain is
    /// bit-identical to [`Planner::plan_for`]`(link)`.
    pub fn two_tier(link: LinkModel) -> TierChain {
        TierChain {
            links: vec![link],
            compute_scale: vec![1.0],
        }
    }

    /// Number of tiers including the edge: `links.len() + 1`.
    pub fn num_tiers(&self) -> usize {
        self.links.len() + 1
    }

    /// Panics unless the chain is well-formed: at least one hop, one
    /// compute scale per hop, every scale finite and non-negative.
    fn assert_valid(&self) {
        assert!(
            !self.links.is_empty(),
            "a tier chain needs at least one hop (K >= 2)"
        );
        assert_eq!(
            self.compute_scale.len(),
            self.links.len(),
            "tier chain has {} hops but {} compute scales (need one per tier beyond the edge)",
            self.links.len(),
            self.compute_scale.len()
        );
        for (k, &scale) in self.compute_scale.iter().enumerate() {
            assert!(
                scale.is_finite() && scale >= 0.0,
                "compute_scale[{k}] = {scale} must be finite and non-negative"
            );
        }
    }
}

/// The solved chain partition: where to cut between each pair of
/// adjacent tiers, the expected time the vector achieves, and what each
/// hop puts on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct ChainPlan {
    /// `cuts[h]`: the stage after which tier `h` hands off to tier
    /// `h+1`. Non-decreasing; `cuts[h] = N` means tier `h` runs to the
    /// final output and nothing crosses hop `h` (or any later hop).
    pub cuts: Vec<usize>,
    /// `E[T]` of the vector — the model value without the tie-break
    /// epsilon, exactly as [`Planner::plan_for`] reports its time.
    pub expected_time_s: f64,
    /// Wire bytes a transferred sample ships on each hop, under the
    /// planner's baked encoding: `alpha(cuts[h])`, or 0 when nothing
    /// crosses the hop (`cuts[h] = N`).
    pub hop_wire_bytes: Vec<u64>,
}

impl ChainPlan {
    /// True when the edge runs the whole net and no hop carries traffic.
    pub fn is_edge_only(&self, num_stages: usize) -> bool {
        self.cuts.first() == Some(&num_stages)
    }

    /// Stages each tier executes, edge first: `[cuts[0], cuts[1] −
    /// cuts[0], …, N − cuts[K-2]]`. Sums to `num_stages`; a
    /// pass-through tier (`cuts[k] = cuts[k-1]`) contributes 0.
    pub fn stage_counts(&self, num_stages: usize) -> Vec<usize> {
        let mut counts = Vec::with_capacity(self.cuts.len() + 1);
        let mut prev = 0usize;
        for &c in &self.cuts {
            counts.push(c - prev);
            prev = c;
        }
        counts.push(num_stages - prev);
        counts
    }
}

impl Planner {
    /// `E[T(cuts)]` of one explicit monotone cut vector under `chain` —
    /// the canonical chain pricing the dynamic program minimizes and
    /// the exhaustive oracle re-implements. `cuts.len()` must equal the
    /// number of hops; entries must be non-decreasing and at most N.
    ///
    /// The arithmetic extends the 2-tier fold without disturbing it:
    /// the edge part is `edge_cost[c0]` (the estimator's fold), and the
    /// transferred part multiplies the survival at the cut into the
    /// right-folded hop/segment chain (see the module doc). With
    /// `chain = TierChain::two_tier(link)` and `cuts = [s]` this is
    /// bit-identical to [`Planner::expected_time`]`(s, link)`.
    pub fn chain_expected_time(&self, chain: &TierChain, cuts: &[usize]) -> f64 {
        chain.assert_valid();
        let view = self.view();
        let core = &*self.core;
        let n = core.n;
        assert_eq!(
            cuts.len(),
            chain.links.len(),
            "cut vector has {} entries for a chain with {} hops",
            cuts.len(),
            chain.links.len()
        );
        for pair in cuts.windows(2) {
            assert!(
                pair[0] <= pair[1],
                "cut vector {cuts:?} is not non-decreasing"
            );
        }
        let c0 = cuts[0];
        let last = *cuts.last().unwrap();
        assert!(last <= n, "cut {last} out of range 0..={n}");

        let mut t = view.edge_cost[c0];
        if c0 < n {
            let surv = view.surv[c0];
            if surv > 0.0 {
                t += surv
                    * (chain.links[0].transfer_time(core.alpha_bytes[c0])
                        + downstream(core, chain, cuts, 1, c0));
            }
        }
        t
    }

    /// Solve for the optimal monotone cut vector under `chain`: the
    /// layered-graph shortest path in O(K·N²), with the same epsilon
    /// tie-break as [`Planner::plan_for`] (see the module doc for the
    /// exact rule). K = 2 collapses bit-identically to `plan_for`.
    pub fn plan_chain(&self, chain: &TierChain) -> ChainPlan {
        self.plan_chain_with_epsilon(chain, self.epsilon)
    }

    /// [`Planner::plan_chain`] with an explicit tie-breaker, for
    /// epsilon-sensitivity sweeps. The view is pinned once for the
    /// whole solve, so a concurrent [`Planner::set_exit_probs`] can
    /// never mix two p's in one plan.
    pub fn plan_chain_with_epsilon(&self, chain: &TierChain, epsilon: f64) -> ChainPlan {
        assert!(
            epsilon > 0.0 && epsilon.is_finite(),
            "epsilon must be positive (paper §V)"
        );
        chain.assert_valid();
        let view = self.view();
        let core = &*self.core;
        let n = core.n;
        // Number of cuts = number of hops = K − 1; tiers beyond the
        // edge are 1..=kmax.
        let kmax = chain.links.len();

        // R[k][i]: cost of tiers k..=kmax given tier k receives the
        // activation cut at stage i — built back to front. The terminal
        // table is the closed form `scale · (C(i) − C(N))`; each
        // intermediate table is a 1-D minimization over its own cut,
        // scanning ascending with `<=` so exact ties pick the larger
        // cut (the lexicographically larger vector). `choice[k-1][i]`
        // remembers the argmin for reconstruction.
        let mut r_next: Vec<f64> = (0..=n)
            .map(|i| chain.compute_scale[kmax - 1] * (core.cloud_suffix[i] - core.cloud_suffix[n]))
            .collect();
        // Choice tables for tiers kmax-1 down to 1 (pushed in that
        // order, reversed below so `choices[k-1]` belongs to tier k).
        let mut choices: Vec<Vec<usize>> = Vec::new();
        for k in (1..kmax).rev() {
            let scale = chain.compute_scale[k - 1];
            let link = chain.links[k];
            let mut r = Vec::with_capacity(n + 1);
            let mut choice = Vec::with_capacity(n + 1);
            for i in 0..=n {
                let mut best = f64::INFINITY;
                let mut best_j = i;
                for j in i..=n {
                    let seg = scale * (core.cloud_suffix[i] - core.cloud_suffix[j]);
                    let cost = if j < n {
                        seg + (link.transfer_time(core.alpha_bytes[j]) + r_next[j])
                    } else {
                        seg
                    };
                    // `<=`: on an exact tie the larger cut wins.
                    if cost <= best {
                        best = cost;
                        best_j = j;
                    }
                }
                r.push(best);
                choice.push(best_j);
            }
            choices.push(choice);
            r_next = r;
        }
        choices.reverse();

        // The edge sweep — the identical fold `plan_with_epsilon` runs,
        // with `R[1]` in place of the bare cloud suffix.
        let mut best_c0 = 0usize;
        let mut best_model = f64::INFINITY;
        let mut best_decision = f64::INFINITY;
        for s in 0..=n {
            let mut model = view.edge_cost[s];
            if s < n {
                let surv = view.surv[s];
                if surv > 0.0 {
                    model +=
                        surv * (chain.links[0].transfer_time(core.alpha_bytes[s]) + r_next[s]);
                }
            }
            let decision = if s < n { model + epsilon } else { model };
            // `<=`: on an exact tie the larger cut (more edge work) wins.
            if decision <= best_decision {
                best_decision = decision;
                best_model = model;
                best_c0 = s;
            }
        }

        // Reconstruct the vector. When nothing ever crosses hop 0 —
        // edge-only, or zero survival at the cut — every tail is
        // cost-tied, and the lexicographically largest (all N, matching
        // the oracle's tie resolution) is reported.
        let mut cuts = Vec::with_capacity(kmax);
        cuts.push(best_c0);
        if best_c0 == n || view.surv[best_c0] <= 0.0 {
            cuts.resize(kmax, n);
        } else {
            let mut at = best_c0;
            for k in 1..kmax {
                let next = if at == n { n } else { choices[k - 1][at] };
                cuts.push(next);
                at = next;
            }
        }

        let hop_wire_bytes: Vec<u64> = cuts
            .iter()
            .map(|&c| if c == n { 0 } else { core.alpha_bytes[c] })
            .collect();

        ChainPlan {
            cuts,
            expected_time_s: best_model,
            hop_wire_bytes,
        }
    }
}

/// Cost of tiers `k..` given tier `k` receives the activation cut at
/// stage `from`: the right fold `seg + (hop + rest)` from the module
/// doc. Recursion depth is K−1 (chains are short).
fn downstream(
    core: &StaticCore,
    chain: &TierChain,
    cuts: &[usize],
    k: usize,
    from: usize,
) -> f64 {
    let n = core.n;
    let kmax = cuts.len();
    let to = if k < kmax { cuts[k] } else { n };
    let seg = chain.compute_scale[k - 1] * (core.cloud_suffix[from] - core.cloud_suffix[to]);
    if k < kmax && to < n {
        seg + (chain.links[k].transfer_time(core.alpha_bytes[to])
            + downstream(core, chain, cuts, k + 1, to))
    } else {
        seg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BranchDesc, BranchyNetDesc};
    use crate::timing::profile::DelayProfile;

    fn fixture(p: f64) -> (BranchyNetDesc, DelayProfile) {
        let desc = BranchyNetDesc {
            stage_names: (1..=5).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![57_600, 18_816, 25_088, 3_456, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: p,
            }],
        };
        let profile = DelayProfile::from_cloud_times(
            vec![1e-3, 2e-3, 1.5e-3, 8e-4, 2e-4],
            3e-4,
            100.0,
        );
        (desc, profile)
    }

    #[test]
    fn two_tier_chain_collapses_to_plan_for_bitwise() {
        let (desc, profile) = fixture(0.6);
        for paper in [true, false] {
            let planner = Planner::new(&desc, &profile, 1e-9, paper);
            for mbps in [0.05, 1.10, 5.85, 18.80, 500.0] {
                let link = LinkModel::new(mbps, 0.01);
                let fixed = planner.plan_for(link);
                let chain = planner.plan_chain(&TierChain::two_tier(link));
                assert_eq!(chain.cuts, vec![fixed.split_after], "mbps={mbps}");
                assert_eq!(
                    chain.expected_time_s.to_bits(),
                    fixed.expected_time_s.to_bits(),
                    "mbps={mbps} paper={paper}"
                );
                assert_eq!(chain.hop_wire_bytes, vec![fixed.wire_bytes]);
                // The explicit pricing agrees with the sweep kernel at
                // every cut, bit for bit.
                for s in 0..=desc.num_stages() {
                    assert_eq!(
                        planner
                            .chain_expected_time(&TierChain::two_tier(link), &[s])
                            .to_bits(),
                        planner.expected_time(s, link).to_bits(),
                        "s={s} mbps={mbps}"
                    );
                }
            }
        }
    }

    #[test]
    fn plan_achieves_its_reported_time_exactly() {
        let (desc, profile) = fixture(0.4);
        let planner = Planner::new(&desc, &profile, 1e-9, false);
        let chain = TierChain {
            links: vec![LinkModel::new(1.10, 0.005), LinkModel::new(100.0, 0.002)],
            compute_scale: vec![4.0, 1.0],
        };
        let plan = planner.plan_chain(&chain);
        assert_eq!(
            planner.chain_expected_time(&chain, &plan.cuts).to_bits(),
            plan.expected_time_s.to_bits()
        );
        assert!(plan.cuts[0] <= plan.cuts[1]);
        assert_eq!(plan.stage_counts(5).iter().sum::<usize>(), 5);
    }

    #[test]
    fn free_middle_tier_on_a_fast_hop_never_hurts() {
        // A zero-cost middle tier behind a fat second hop: the 3-tier
        // optimum can only improve on (or equal) the best 2-tier plan,
        // because every [s, N] vector prices identically to the 2-tier
        // plan at split s.
        let (desc, profile) = fixture(0.3);
        let planner = Planner::new(&desc, &profile, 1e-9, false);
        let hop0 = LinkModel::new(1.10, 0.0);
        let chain = TierChain {
            links: vec![hop0, LinkModel::new(1000.0, 0.001)],
            compute_scale: vec![0.0, 1.0],
        };
        let two = planner.plan_for(hop0);
        let three = planner.plan_chain(&chain);
        assert!(three.expected_time_s <= two.expected_time_s);
        // On a unit-scale chain the all-on-middle vector [s, N] prices
        // bit-identically to the 2-tier plan at the same first cut: the
        // second hop is never taken.
        let unit = TierChain {
            links: chain.links.clone(),
            compute_scale: vec![1.0, 1.0],
        };
        for s in 0..=5 {
            assert_eq!(
                planner.chain_expected_time(&unit, &[s, 5]).to_bits(),
                planner.expected_time(s, hop0).to_bits(),
                "all-on-middle vector must price as the 2-tier split {s}"
            );
        }
    }

    #[test]
    fn hand_computed_three_tier_vector() {
        // No branches, paper mode: E[T] is a plain sum we can write out
        // by hand. 2 stages, cuts = [1, 1]: edge runs stage 1, the
        // middle is a pass-through, the terminal runs stage 2 at 2x.
        let desc = BranchyNetDesc {
            stage_names: vec!["s1".into(), "s2".into()],
            stage_out_bytes: vec![1_000_000, 8],
            input_bytes: 500_000,
            branches: vec![],
        };
        // gamma = 10: t_edge = 10 * t_cloud.
        let profile = DelayProfile::from_cloud_times(vec![0.002, 0.01], 0.0, 10.0);
        let planner = Planner::new(&desc, &profile, 1e-9, true);
        let chain = TierChain {
            links: vec![LinkModel::new(8.0, 0.1), LinkModel::new(80.0, 0.01)],
            compute_scale: vec![0.5, 2.0],
        };
        let got = planner.chain_expected_time(&chain, &[1, 1]);
        let hop0 = 1_000_000.0 * 8.0 / 8e6 + 0.1; // 1.1 s
        let hop1 = 1_000_000.0 * 8.0 / 80e6 + 0.01; // 0.11 s
        let want = 0.02 + (hop0 + (0.5 * 0.0 + (hop1 + 2.0 * 0.01)));
        assert!((got - want).abs() < 1e-12, "got {got}, want {want}");
    }

    #[test]
    fn p_one_reports_the_all_edge_tail() {
        // With p = 1 nothing survives past the branch: every tail is
        // cost-tied and the plan must report the lexicographically
        // largest (all N), matching the exhaustive oracle's tie rule.
        let (desc, profile) = fixture(1.0);
        let planner = Planner::new(&desc, &profile, 1e-9, true);
        let chain = TierChain {
            links: vec![LinkModel::new(0.05, 0.0), LinkModel::new(1.0, 0.0)],
            compute_scale: vec![1.0, 1.0],
        };
        let plan = planner.plan_chain(&chain);
        assert_eq!(plan.cuts, vec![5, 5]);
        assert!(plan.is_edge_only(5));
        assert_eq!(plan.hop_wire_bytes, vec![0, 0]);
        assert_eq!(
            plan.expected_time_s.to_bits(),
            profile.t_edge[0].to_bits()
        );
    }

    #[test]
    #[should_panic(expected = "not non-decreasing")]
    fn decreasing_cut_vector_panics() {
        let (desc, profile) = fixture(0.5);
        let planner = Planner::new(&desc, &profile, 1e-9, false);
        let chain = TierChain {
            links: vec![LinkModel::new(1.0, 0.0), LinkModel::new(1.0, 0.0)],
            compute_scale: vec![1.0, 1.0],
        };
        let _ = planner.chain_expected_time(&chain, &[3, 1]);
    }

    #[test]
    #[should_panic(expected = "compute scales")]
    fn mismatched_scales_panic() {
        let (desc, profile) = fixture(0.5);
        let planner = Planner::new(&desc, &profile, 1e-9, false);
        let chain = TierChain {
            links: vec![LinkModel::new(1.0, 0.0)],
            compute_scale: vec![1.0, 1.0],
        };
        let _ = planner.plan_chain(&chain);
    }
}
