//! Graph substrate: generic weighted DAG/digraph storage, Dijkstra
//! shortest path (the paper's solution algorithm, §V), and a Bellman–Ford
//! oracle used by the property tests to cross-check Dijkstra.

pub mod bellman_ford;
pub mod dag;
pub mod dijkstra;

pub use dag::{Graph, NodeId};
pub use dijkstra::{shortest_path, PathResult};
