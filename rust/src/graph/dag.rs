//! Directed graph with f64 link weights, adjacency-list storage, and the
//! validation/topo-sort helpers the partitioner relies on.
//!
//! Nodes carry a string label (layer names like "conv1_e", "v2*" — useful
//! for debugging the G'_BDNN construction and for reporting which layer a
//! path vertex corresponds to).

use std::collections::VecDeque;

/// Index-based node handle.
pub type NodeId = usize;

#[derive(Debug, Clone)]
pub struct Edge {
    pub to: NodeId,
    pub weight: f64,
}

#[derive(Debug, Clone, Default)]
pub struct Graph {
    labels: Vec<String>,
    adj: Vec<Vec<Edge>>,
    edge_count: usize,
}

impl Graph {
    pub fn new() -> Self {
        Graph::default()
    }

    pub fn with_capacity(nodes: usize) -> Self {
        Graph {
            labels: Vec::with_capacity(nodes),
            adj: Vec::with_capacity(nodes),
            edge_count: 0,
        }
    }

    pub fn add_node(&mut self, label: impl Into<String>) -> NodeId {
        self.labels.push(label.into());
        self.adj.push(Vec::new());
        self.labels.len() - 1
    }

    /// Add a weighted directed link. Weights must be finite and >= 0
    /// (Dijkstra's precondition; the paper's weights are all delays).
    pub fn add_edge(&mut self, from: NodeId, to: NodeId, weight: f64) {
        assert!(from < self.len() && to < self.len(), "node out of range");
        assert!(
            weight.is_finite() && weight >= 0.0,
            "edge weight must be finite and non-negative, got {weight}"
        );
        self.adj[from].push(Edge { to, weight });
        self.edge_count += 1;
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn edge_count(&self) -> usize {
        self.edge_count
    }

    pub fn label(&self, n: NodeId) -> &str {
        &self.labels[n]
    }

    pub fn edges(&self, n: NodeId) -> &[Edge] {
        &self.adj[n]
    }

    pub fn find_node(&self, label: &str) -> Option<NodeId> {
        self.labels.iter().position(|l| l == label)
    }

    /// Kahn topological sort; `None` if the graph has a cycle.
    pub fn topo_sort(&self) -> Option<Vec<NodeId>> {
        let mut indeg = vec![0usize; self.len()];
        for edges in &self.adj {
            for e in edges {
                indeg[e.to] += 1;
            }
        }
        let mut queue: VecDeque<NodeId> = indeg
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(n) = queue.pop_front() {
            order.push(n);
            for e in &self.adj[n] {
                indeg[e.to] -= 1;
                if indeg[e.to] == 0 {
                    queue.push_back(e.to);
                }
            }
        }
        (order.len() == self.len()).then_some(order)
    }

    pub fn is_dag(&self) -> bool {
        self.topo_sort().is_some()
    }

    /// All nodes reachable from `start`.
    pub fn reachable_from(&self, start: NodeId) -> Vec<bool> {
        let mut seen = vec![false; self.len()];
        let mut stack = vec![start];
        seen[start] = true;
        while let Some(n) = stack.pop() {
            for e in &self.adj[n] {
                if !seen[e.to] {
                    seen[e.to] = true;
                    stack.push(e.to);
                }
            }
        }
        seen
    }

    /// Graphviz dot output — debugging aid for the G'_BDNN construction.
    pub fn to_dot(&self) -> String {
        let mut s = String::from("digraph G {\n");
        for (i, l) in self.labels.iter().enumerate() {
            s.push_str(&format!("  n{i} [label=\"{l}\"];\n"));
        }
        for (i, edges) in self.adj.iter().enumerate() {
            for e in edges {
                s.push_str(&format!(
                    "  n{i} -> n{} [label=\"{:.3e}\"];\n",
                    e.to, e.weight
                ));
            }
        }
        s.push('}');
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // a -> b -> d, a -> c -> d
        let mut g = Graph::new();
        let a = g.add_node("a");
        let b = g.add_node("b");
        let c = g.add_node("c");
        let d = g.add_node("d");
        g.add_edge(a, b, 1.0);
        g.add_edge(a, c, 2.0);
        g.add_edge(b, d, 3.0);
        g.add_edge(c, d, 1.0);
        g
    }

    #[test]
    fn build_and_query() {
        let g = diamond();
        assert_eq!(g.len(), 4);
        assert_eq!(g.edge_count(), 4);
        assert_eq!(g.label(0), "a");
        assert_eq!(g.find_node("c"), Some(2));
        assert_eq!(g.find_node("zz"), None);
        assert_eq!(g.edges(0).len(), 2);
    }

    #[test]
    fn topo_sort_of_dag() {
        let g = diamond();
        let order = g.topo_sort().unwrap();
        let pos: Vec<usize> = (0..4).map(|n| order.iter().position(|&x| x == n).unwrap()).collect();
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
        assert!(g.is_dag());
    }

    #[test]
    fn cycle_detected() {
        let mut g = Graph::new();
        let a = g.add_node("a");
        let b = g.add_node("b");
        g.add_edge(a, b, 1.0);
        g.add_edge(b, a, 1.0);
        assert!(!g.is_dag());
    }

    #[test]
    fn reachability() {
        let mut g = diamond();
        let e = g.add_node("island");
        let seen = g.reachable_from(0);
        assert!(seen[0] && seen[1] && seen[2] && seen[3]);
        assert!(!seen[e]);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn rejects_negative_weight() {
        let mut g = Graph::new();
        let a = g.add_node("a");
        let b = g.add_node("b");
        g.add_edge(a, b, -1.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_dangling_edge() {
        let mut g = Graph::new();
        let a = g.add_node("a");
        g.add_edge(a, 5, 1.0);
    }

    #[test]
    fn dot_output_contains_nodes() {
        let dot = diamond().to_dot();
        assert!(dot.contains("digraph"));
        assert!(dot.contains("n0 -> n1"));
    }
}
