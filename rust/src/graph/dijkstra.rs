//! Binary-heap Dijkstra with predecessor recovery — the paper's §V
//! algorithm, O((m + n) log n) with the std BinaryHeap (the paper quotes
//! O(m + n log n) for a Fibonacci heap; on graphs this size the binary
//! heap is faster in practice and the complexity class argument —
//! polynomial, vs brute force — is unchanged).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::dag::{Graph, NodeId};

/// Result of a shortest-path query.
#[derive(Debug, Clone, PartialEq)]
pub struct PathResult {
    /// Total cost of the path.
    pub cost: f64,
    /// Node sequence from source to target (inclusive).
    pub nodes: Vec<NodeId>,
}

/// Heap entry; reversed ordering turns std's max-heap into a min-heap.
#[derive(Debug, PartialEq)]
struct Entry {
    dist: f64,
    node: NodeId,
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse on distance; tie-break on node id for determinism.
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.node.cmp(&self.node))
    }
}

/// Dijkstra from `source` to `target`. Returns `None` if unreachable.
///
/// Weights must be non-negative (enforced by `Graph::add_edge`).
pub fn shortest_path(g: &Graph, source: NodeId, target: NodeId) -> Option<PathResult> {
    let n = g.len();
    assert!(source < n && target < n, "node out of range");
    let mut dist = vec![f64::INFINITY; n];
    let mut prev: Vec<Option<NodeId>> = vec![None; n];
    let mut done = vec![false; n];
    let mut heap = BinaryHeap::with_capacity(n);

    dist[source] = 0.0;
    heap.push(Entry {
        dist: 0.0,
        node: source,
    });

    while let Some(Entry { dist: d, node }) = heap.pop() {
        if done[node] {
            continue; // stale entry
        }
        done[node] = true;
        if node == target {
            break;
        }
        for e in g.edges(node) {
            let nd = d + e.weight;
            if nd < dist[e.to] {
                dist[e.to] = nd;
                prev[e.to] = Some(node);
                heap.push(Entry {
                    dist: nd,
                    node: e.to,
                });
            }
        }
    }

    if dist[target].is_infinite() {
        return None;
    }
    // Recover the path.
    let mut nodes = vec![target];
    let mut cur = target;
    while let Some(p) = prev[cur] {
        nodes.push(p);
        cur = p;
        if cur == source {
            break;
        }
    }
    if *nodes.last().unwrap() != source {
        // target == source case.
        if source != target {
            return None;
        }
    }
    nodes.reverse();
    Some(PathResult {
        cost: dist[target],
        nodes,
    })
}

/// Single-source distances to every node (used by diagnostics and tests).
pub fn distances_from(g: &Graph, source: NodeId) -> Vec<f64> {
    let n = g.len();
    let mut dist = vec![f64::INFINITY; n];
    let mut done = vec![false; n];
    let mut heap = BinaryHeap::with_capacity(n);
    dist[source] = 0.0;
    heap.push(Entry {
        dist: 0.0,
        node: source,
    });
    while let Some(Entry { dist: d, node }) = heap.pop() {
        if done[node] {
            continue;
        }
        done[node] = true;
        for e in g.edges(node) {
            let nd = d + e.weight;
            if nd < dist[e.to] {
                dist[e.to] = nd;
                heap.push(Entry {
                    dist: nd,
                    node: e.to,
                });
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Graph {
        //      1       4
        //  s ----> a ----> t
        //   \      |      ^
        //    \2    |0.5   |1
        //     \--> b -----/
        let mut g = Graph::new();
        let s = g.add_node("s");
        let a = g.add_node("a");
        let b = g.add_node("b");
        let t = g.add_node("t");
        g.add_edge(s, a, 1.0);
        g.add_edge(s, b, 2.0);
        g.add_edge(a, t, 4.0);
        g.add_edge(a, b, 0.5);
        g.add_edge(b, t, 1.0);
        g
    }

    #[test]
    fn finds_optimal_path() {
        let g = sample();
        let r = shortest_path(&g, 0, 3).unwrap();
        assert!((r.cost - 2.5).abs() < 1e-12);
        assert_eq!(r.nodes, vec![0, 1, 2, 3]); // s -> a -> b -> t
    }

    #[test]
    fn unreachable_is_none() {
        let mut g = sample();
        let iso = g.add_node("iso");
        assert!(shortest_path(&g, 0, iso).is_none());
    }

    #[test]
    fn source_equals_target() {
        let g = sample();
        let r = shortest_path(&g, 1, 1).unwrap();
        assert_eq!(r.cost, 0.0);
        assert_eq!(r.nodes, vec![1]);
    }

    #[test]
    fn zero_weight_edges_ok() {
        let mut g = Graph::new();
        let a = g.add_node("a");
        let b = g.add_node("b");
        let c = g.add_node("c");
        g.add_edge(a, b, 0.0);
        g.add_edge(b, c, 0.0);
        let r = shortest_path(&g, a, c).unwrap();
        assert_eq!(r.cost, 0.0);
        assert_eq!(r.nodes, vec![a, b, c]);
    }

    #[test]
    fn distances_match_path_costs() {
        let g = sample();
        let dist = distances_from(&g, 0);
        for t in 0..g.len() {
            match shortest_path(&g, 0, t) {
                Some(r) => assert!((r.cost - dist[t]).abs() < 1e-12),
                None => assert!(dist[t].is_infinite()),
            }
        }
    }

    #[test]
    fn long_chain() {
        let mut g = Graph::new();
        let nodes: Vec<_> = (0..10_000).map(|i| g.add_node(format!("n{i}"))).collect();
        for w in nodes.windows(2) {
            g.add_edge(w[0], w[1], 0.001);
        }
        let r = shortest_path(&g, nodes[0], *nodes.last().unwrap()).unwrap();
        assert_eq!(r.nodes.len(), 10_000);
        assert!((r.cost - 9.999).abs() < 1e-6);
    }
}
