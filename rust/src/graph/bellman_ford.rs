//! Bellman–Ford shortest path: the slow, obviously-correct oracle the
//! property tests compare Dijkstra against (never used on a hot path).

use super::dag::{Graph, NodeId};
use super::dijkstra::PathResult;

/// O(n * m) shortest path. Same contract as `dijkstra::shortest_path`.
pub fn shortest_path(g: &Graph, source: NodeId, target: NodeId) -> Option<PathResult> {
    let n = g.len();
    let mut dist = vec![f64::INFINITY; n];
    let mut prev: Vec<Option<NodeId>> = vec![None; n];
    dist[source] = 0.0;

    for _ in 0..n.max(1) - 1 {
        let mut changed = false;
        for u in 0..n {
            if dist[u].is_infinite() {
                continue;
            }
            for e in g.edges(u) {
                let nd = dist[u] + e.weight;
                if nd < dist[e.to] {
                    dist[e.to] = nd;
                    prev[e.to] = Some(u);
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }

    if dist[target].is_infinite() {
        return None;
    }
    let mut nodes = vec![target];
    let mut cur = target;
    while let Some(p) = prev[cur] {
        nodes.push(p);
        cur = p;
    }
    nodes.reverse();
    if nodes[0] != source && source != target {
        return None;
    }
    Some(PathResult {
        cost: dist[target],
        nodes,
    })
}

#[cfg(test)]
mod tests {
    use super::super::dijkstra;
    use super::*;
    use crate::util::rng::Pcg32;

    /// Random layered DAGs: Bellman-Ford and Dijkstra must agree on cost.
    #[test]
    fn agrees_with_dijkstra_on_random_dags() {
        let mut rng = Pcg32::seeded(42);
        for case in 0..50 {
            let layers = 2 + rng.below(6) as usize;
            let width = 1 + rng.below(5) as usize;
            let mut g = Graph::new();
            let mut layer_nodes: Vec<Vec<NodeId>> = Vec::new();
            for l in 0..layers {
                let mut nodes = Vec::new();
                for i in 0..width {
                    nodes.push(g.add_node(format!("l{l}n{i}")));
                }
                layer_nodes.push(nodes);
            }
            for l in 0..layers - 1 {
                for &from in &layer_nodes[l] {
                    for &to in &layer_nodes[l + 1] {
                        if rng.bool(0.7) {
                            g.add_edge(from, to, rng.range_f64(0.0, 10.0));
                        }
                    }
                }
            }
            let s = layer_nodes[0][0];
            let t = *layer_nodes[layers - 1].last().unwrap();
            let a = dijkstra::shortest_path(&g, s, t);
            let b = shortest_path(&g, s, t);
            match (a, b) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert!(
                        (x.cost - y.cost).abs() < 1e-9,
                        "case {case}: dijkstra {} vs bellman-ford {}",
                        x.cost,
                        y.cost
                    );
                }
                (x, y) => panic!("case {case}: reachability disagreement {x:?} vs {y:?}"),
            }
        }
    }
}
