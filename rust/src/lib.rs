//! # branchyserve
//!
//! Edge/cloud serving framework for early-exit (BranchyNet) DNNs with
//! optimal shortest-path partitioning — a three-layer Rust + JAX + Pallas
//! reproduction of *"Inference Time Optimization Using BranchyNet
//! Partitioning"* (Pacheco & Couto, IEEE ISCC 2020).
//!
//! The paper's contribution — choosing the layer at which to split a
//! BranchyNet between an edge device and a cloud server so that the
//! *expected* inference time (including the probability of early exit at
//! a side branch) is minimized — is implemented in [`partition`]: the
//! `G'_BDNN` graph construction (§V, Eqs. 7–8) plus Dijkstra. Around it
//! sits a five-layer serving system (partition → planner → coordinator
//! → fleet → server; `ARCHITECTURE.md` at the repo root is the prose
//! map of how they fit together):
//!
//! * [`model`] — the B-AlexNet stage graph loaded from `artifacts/manifest.json`;
//! * [`timing`] — the inference-time model (Eqs. 1–6);
//! * [`network`] — bandwidth profiles (3G/4G/Wi-Fi), traces, simulated channels;
//! * [`runtime`] — PJRT CPU execution of the AOT-compiled HLO artifacts;
//! * [`profiler`] — per-layer `t_i^c` measurement;
//! * [`planner`] — precomputed, cached, incremental replanning: the single
//!   owner of "model + profile + epsilon + strategy → plan", with a
//!   two-layer core (p-independent `StaticCore`, cheap swappable exit-
//!   probability views), an adaptive replan loop for time-varying
//!   uplinks, and an exit-rate estimator for drift-triggered p updates;
//! * [`coordinator`] — router, dynamic batcher, early-exit scheduler,
//!   metrics; its cloud half is a [`coordinator::CloudExec`]: in-process,
//!   or a remote cloud-stage server with local fallback;
//! * [`fleet`] — sharded multi-class serving: per-link-class planners
//!   (3G/4G/WiFi or TOML-defined) behind a routing fleet coordinator,
//!   with per-request planning, online exit-rate estimation and
//!   branch-probing recovery;
//! * [`server`] / [`workload`] — the wire protocol (including the
//!   partial-inference frames that carry cut activations between
//!   machines), the TCP accept loop, the cloud-stage server and the
//!   remote cloud client, plus load generation;
//! * [`scenario`] — the scenario harness: a declarative `.toml` DSL for
//!   scripted load curves, link churn, cloud brownouts and exit-rate
//!   drift, replayed against a real fleet in deterministic virtual time
//!   and judged by an SLO block (`branchyserve scenario run`);
//! * [`experiments`] — drivers regenerating the paper's Figures 4, 5, 6.
//!
//! The partition is physically realizable: `branchyserve serve
//! --cloud-addr HOST:PORT` runs the edge half against `branchyserve
//! cloud-serve` on another machine, with intermediate activations
//! crossing a real network at exactly the planned split (see
//! `docs/serving.md` for the two-terminal demo).
//!
//! Python/JAX/Pallas exist only at build time (`make artifacts`); the
//! request path is pure Rust. Without the `xla-pjrt` feature the
//! [`runtime`] falls back to a deterministic simulated backend, so the
//! whole serving stack still runs end-to-end offline.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod fleet;
pub mod graph;
pub mod harness;
pub mod model;
pub mod network;
pub mod partition;
pub mod planner;
pub mod profiler;
pub mod runtime;
pub mod scenario;
pub mod server;
pub mod testing;
pub mod timing;
pub mod util;
pub mod workload;
