//! Artifact store: lazily compiles HLO-text artifacts on the PJRT client
//! and caches the loaded executables keyed by file name.
//!
//! Compilation happens once per (artifact, process); the serving hot path
//! only ever hits the cache. `warmup` precompiles everything a plan needs
//! so the first request doesn't pay XLA compile time.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::settings::Flavor;
use crate::model::Manifest;

use super::tensor::HostTensor;

/// A compiled artifact plus metadata.
pub struct LoadedExecutable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub compile_time_s: f64,
}

impl LoadedExecutable {
    /// Run with a single input tensor; unwraps the 1-tuple output
    /// convention (`return_tuple=True` at lowering).
    pub fn run1(&self, input: &HostTensor) -> Result<HostTensor> {
        let lit = input.to_literal()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching output literal")?
            .to_tuple1()
            .context("unwrapping 1-tuple output")?;
        HostTensor::from_literal(&out)
    }

    /// Run producing two outputs (the branch artifact: probs, entropy).
    pub fn run2(&self, input: &HostTensor) -> Result<(HostTensor, HostTensor)> {
        let lit = input.to_literal()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .with_context(|| format!("executing {}", self.name))?;
        let (a, b) = result[0][0]
            .to_literal_sync()
            .context("fetching output literal")?
            .to_tuple2()
            .context("unwrapping 2-tuple output")?;
        Ok((HostTensor::from_literal(&a)?, HostTensor::from_literal(&b)?))
    }
}

/// Lazily-compiling artifact cache over one PJRT client.
pub struct ArtifactStore {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedExecutable>>>,
}

impl ArtifactStore {
    /// Create with a fresh CPU PJRT client rooted at the artifacts dir.
    pub fn open(dir: &std::path::Path) -> Result<ArtifactStore> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(ArtifactStore {
            client,
            dir: dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }

    /// Fetch (compiling if needed) an artifact by file name.
    pub fn get(&self, name: &str) -> Result<std::sync::Arc<LoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(name);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let compile_time_s = t0.elapsed().as_secs_f64();
        log::debug!("compiled {name} in {compile_time_s:.3}s");
        let loaded = std::sync::Arc::new(LoadedExecutable {
            name: name.to_string(),
            exe,
            compile_time_s,
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Precompile every stage/branch artifact of one flavor at the given
    /// batch sizes. Returns total compile seconds.
    pub fn warmup(&self, manifest: &Manifest, flavor: Flavor, batches: &[usize]) -> Result<f64> {
        let mut total = 0.0;
        for stage in &manifest.stages {
            for &b in batches {
                total += self.get(stage.artifact(flavor, b)?)?.compile_time_s;
            }
        }
        for &b in batches {
            total += self.get(manifest.branch.artifact(flavor, b)?)?.compile_time_s;
        }
        Ok(total)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
