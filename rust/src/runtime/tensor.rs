//! Host-side f32 tensor: the activation format flowing between pipeline
//! stages, the network channel, and the PJRT boundary.
//!
//! Storage is a shared `Arc<[f32]>`: cloning a tensor is a refcount
//! bump, so the serving path (wire decode → admission queue →
//! coordinator hops → cloud transfer queue) shares one allocation per
//! sample instead of copying the payload at every channel hop. Shapes
//! stay small `Vec`s; all mutating operations (`stack`, `pad_batch`,
//! …) build fresh buffers, so sharing is never observable.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

/// Dense row-major f32 tensor over shared storage.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    shape: Vec<usize>,
    data: Arc<[f32]>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elements, data has {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(HostTensor {
            shape,
            data: data.into(),
        })
    }

    /// Wrap an already-shared buffer without copying it — the wire
    /// decoder's entry point: the frame parser collects payload floats
    /// straight into an `Arc<[f32]>` and every later hop clones the
    /// handle.
    pub fn from_shared(shape: Vec<usize>, data: Arc<[f32]>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elements, data has {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(HostTensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor {
            shape,
            data: vec![0.0; n].into(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Copy the elements out into an owned `Vec`. The storage is
    /// shared, so this always allocates; prefer [`HostTensor::data`]
    /// when a borrow will do.
    pub fn into_data(self) -> Vec<f32> {
        self.data.to_vec()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn size_bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    /// Leading (batch) dimension.
    pub fn batch(&self) -> usize {
        *self.shape.first().unwrap_or(&0)
    }

    /// Per-sample element count (product of non-batch dims).
    pub fn sample_elems(&self) -> usize {
        self.shape[1..].iter().product()
    }

    /// Slice of sample `i`'s elements.
    pub fn sample(&self, i: usize) -> &[f32] {
        let k = self.sample_elems();
        &self.data[i * k..(i + 1) * k]
    }

    /// Stack per-sample tensors into a batch (all must share shape).
    pub fn stack(samples: &[HostTensor]) -> Result<HostTensor> {
        let first = samples.first().context("stack of zero tensors")?;
        let mut data = Vec::with_capacity(samples.len() * first.len());
        for s in samples {
            if s.shape != first.shape {
                bail!("stack shape mismatch: {:?} vs {:?}", s.shape, first.shape);
            }
            data.extend_from_slice(&s.data);
        }
        let mut shape = vec![samples.len()];
        shape.extend_from_slice(&first.shape);
        HostTensor::new(shape, data)
    }

    /// Split a batched tensor into per-sample tensors (dropping the batch
    /// dim from each).
    pub fn unstack(&self) -> Vec<HostTensor> {
        let k = self.sample_elems();
        let sample_shape: Vec<usize> = self.shape[1..].to_vec();
        (0..self.batch())
            .map(|i| HostTensor {
                shape: sample_shape.clone(),
                data: self.data[i * k..(i + 1) * k].into(),
            })
            .collect()
    }

    /// Take the first `n` samples of a batched tensor.
    pub fn take_batch(&self, n: usize) -> HostTensor {
        assert!(n <= self.batch());
        let k = self.sample_elems();
        let mut shape = self.shape.clone();
        shape[0] = n;
        HostTensor {
            shape,
            data: self.data[..n * k].into(),
        }
    }

    /// Pad the batch dimension to `n` by repeating the last sample (the
    /// batcher's shape-specialization filler; padded outputs are dropped).
    pub fn pad_batch(&self, n: usize) -> HostTensor {
        assert!(n >= self.batch() && self.batch() > 0);
        let mut data = self.data.to_vec();
        let last = self.sample(self.batch() - 1).to_vec();
        for _ in self.batch()..n {
            data.extend_from_slice(&last);
        }
        let mut shape = self.shape.clone();
        shape[0] = n;
        HostTensor {
            shape,
            data: data.into(),
        }
    }

    // ---------------------------------------------------------------- XLA

    /// Convert to an XLA literal of matching shape.
    ///
    /// Single-copy path (§Perf L3-2): build the literal directly from the
    /// raw bytes instead of `vec1(..).reshape(..)`, which copies twice.
    #[cfg(feature = "xla-pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.shape,
            bytes,
        )
        .context("creating literal from raw data")
    }

    /// Build from an XLA literal (f32 arrays only).
    #[cfg(feature = "xla-pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().context("literal to f32 vec")?;
        HostTensor::new(dims, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_validates_shape() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let shared: Arc<[f32]> = vec![0.0; 6].into();
        assert!(HostTensor::from_shared(vec![2, 3], shared.clone()).is_ok());
        assert!(HostTensor::from_shared(vec![7], shared).is_err());
    }

    #[test]
    fn clones_share_storage() {
        let t = HostTensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let c = t.clone();
        // The clone is a handle to the same allocation, not a copy —
        // this is the zero-copy admission contract.
        assert!(std::ptr::eq(t.data().as_ptr(), c.data().as_ptr()));
        assert_eq!(t, c);
        // into_data copies out without disturbing other handles.
        assert_eq!(c.into_data(), vec![1., 2., 3., 4.]);
        assert_eq!(t.data(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn stack_unstack_roundtrip() {
        let a = HostTensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = HostTensor::new(vec![2, 2], vec![5., 6., 7., 8.]).unwrap();
        let batch = HostTensor::stack(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(batch.shape(), &[2, 2, 2]);
        assert_eq!(batch.batch(), 2);
        let parts = batch.unstack();
        assert_eq!(parts, vec![a, b]);
    }

    #[test]
    fn stack_rejects_mismatched() {
        let a = HostTensor::zeros(vec![2, 2]);
        let b = HostTensor::zeros(vec![3]);
        assert!(HostTensor::stack(&[a, b]).is_err());
        assert!(HostTensor::stack(&[]).is_err());
    }

    #[test]
    fn pad_and_take_batch() {
        let t = HostTensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let padded = t.pad_batch(4);
        assert_eq!(padded.shape(), &[4, 3]);
        assert_eq!(padded.sample(2), &[4., 5., 6.]); // repeated last
        assert_eq!(padded.sample(3), &[4., 5., 6.]);
        let back = padded.take_batch(2);
        assert_eq!(back, t);
    }

    #[test]
    fn sample_views() {
        let t = HostTensor::new(vec![2, 2, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        assert_eq!(t.sample_elems(), 4);
        assert_eq!(t.sample(1), &[4., 5., 6., 7.]);
        assert_eq!(t.size_bytes(), 32);
    }
}
