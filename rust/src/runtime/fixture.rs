//! Fixture loading: the raw-f32 tensors `aot.py` dumped for round-trip
//! tests and the Fig. 6 experiment.

use anyhow::{bail, Context, Result};

use crate::model::manifest::FixtureInfo;
use crate::util::bytes::read_f32_file;

use super::tensor::HostTensor;

/// Load a fixture into a tensor, validating size against its shape.
pub fn load(info: &FixtureInfo) -> Result<HostTensor> {
    let data = read_f32_file(&info.path)
        .with_context(|| format!("reading fixture {}", info.path.display()))?;
    let want: usize = info.shape.iter().product();
    if data.len() != want {
        bail!(
            "fixture {} has {} f32s, shape {:?} wants {}",
            info.path.display(),
            data.len(),
            info.shape,
            want
        );
    }
    HostTensor::new(info.shape.clone(), data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::write_f32_file;
    use std::path::PathBuf;

    #[test]
    fn roundtrip_and_validation() {
        let dir = std::env::temp_dir().join("branchyserve_fixture_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p: PathBuf = dir.join("t.bin");
        write_f32_file(&p, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();

        let ok = load(&FixtureInfo {
            path: p.clone(),
            shape: vec![2, 3],
        })
        .unwrap();
        assert_eq!(ok.shape(), &[2, 3]);
        assert_eq!(ok.data()[4], 5.0);

        let bad = load(&FixtureInfo {
            path: p.clone(),
            shape: vec![7],
        });
        assert!(bad.is_err());
        std::fs::remove_file(&p).unwrap();
    }
}
