//! Execution runtime: the compute behind the serving path, with two
//! interchangeable backends behind one [`InferenceEngine`] handle.
//!
//! * **PJRT** (`feature = "xla-pjrt"`): loads the AOT-compiled HLO-text
//!   artifacts `make artifacts` produced and executes them on a CPU PJRT
//!   client (`HloModuleProto::from_text_file` → `PjRtClient::compile`).
//!   Python never runs on the request path. Off by default because the
//!   `xla` crate is not in the offline vendor set.
//! * **Sim** ([`sim::SimNet`], always available): a deterministic
//!   pure-Rust stand-in that realizes the same manifest contract
//!   (per-stage shapes, batched execution, a branch head emitting
//!   (probs, entropy)) with cheap arithmetic and an optional synthetic
//!   per-stage compute cost. It exists so the serving stack — batcher,
//!   coordinator, fleet, TCP front-end, benches — runs end-to-end in
//!   environments without artifacts or XLA.

#[cfg(feature = "xla-pjrt")]
pub mod artifact;
pub mod engine;
pub mod fixture;
pub mod sim;
pub mod tensor;

#[cfg(feature = "xla-pjrt")]
pub use artifact::ArtifactStore;
pub use engine::{BranchOutput, InferenceEngine};
pub use sim::SimNet;
pub use tensor::HostTensor;
