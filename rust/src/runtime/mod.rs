//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them on the CPU PJRT client from the Rust request path.
//!
//! Python never runs here — `make artifacts` produced the HLO text once;
//! this module parses it (`HloModuleProto::from_text_file`), compiles it
//! (`PjRtClient::compile`) and executes it with activation tensors.

pub mod artifact;
pub mod engine;
pub mod fixture;
pub mod tensor;

pub use artifact::ArtifactStore;
pub use engine::{BranchOutput, InferenceEngine};
pub use tensor::HostTensor;
