//! Inference engine: a Send + Sync handle to a dedicated executor thread
//! that owns the (non-Send) PJRT client and artifact cache.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and must stay on one
//! thread; the serving coordinator, TCP connections and benches all need
//! to call it from many threads. Each `InferenceEngine` therefore spawns
//! one executor thread owning an [`ArtifactStore`] and services requests
//! over a channel. This also mirrors the paper's deployment: the *edge
//! device* and the *cloud server* are separate compute resources — the
//! coordinator gives each node its own engine (its own PJRT client), so
//! edge and cloud stages execute concurrently like the real pipeline.
//!
//! `run_stages(a..=b)` composes per-stage executables to realize any
//! partition; `run_branch` evaluates the side branch's fused
//! (probs, entropy) head.

use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::config::settings::Flavor;
use crate::model::Manifest;

use super::artifact::ArtifactStore;
use super::tensor::HostTensor;

/// Output of a branch evaluation for one batch.
#[derive(Debug, Clone)]
pub struct BranchOutput {
    /// (B, num_classes) class probabilities.
    pub probs: HostTensor,
    /// (B,) entropy in nats.
    pub entropy: Vec<f32>,
}

enum Job {
    RunStages {
        from: usize,
        to: usize,
        input: HostTensor,
        reply: mpsc::Sender<Result<HostTensor>>,
    },
    RunFull {
        input: HostTensor,
        reply: mpsc::Sender<Result<HostTensor>>,
    },
    RunBranch {
        input: HostTensor,
        reply: mpsc::Sender<Result<BranchOutput>>,
    },
    Warmup {
        reply: mpsc::Sender<Result<f64>>,
    },
    CachedCount {
        reply: mpsc::Sender<usize>,
    },
}

#[derive(Clone)]
pub struct InferenceEngine {
    tx: Arc<Mutex<mpsc::Sender<Job>>>,
    manifest: Arc<Manifest>,
    flavor: Flavor,
}

impl InferenceEngine {
    /// Spawn the executor thread (which creates its own PJRT CPU client)
    /// and return the handle. `name` labels the thread ("edge", "cloud").
    pub fn open(
        dir: &std::path::Path,
        manifest: Manifest,
        flavor: Flavor,
        name: &str,
    ) -> Result<InferenceEngine> {
        let (tx, rx) = mpsc::channel::<Job>();
        let dir = dir.to_path_buf();
        let worker_manifest = manifest.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name(format!("pjrt-{name}"))
            .spawn(move || {
                let store = match ArtifactStore::open(&dir) {
                    Ok(s) => {
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                executor_loop(store, worker_manifest, flavor, rx);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))??;
        Ok(InferenceEngine {
            tx: Arc::new(Mutex::new(tx)),
            manifest: Arc::new(manifest),
            flavor,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn flavor(&self) -> Flavor {
        self.flavor
    }

    fn send(&self, job: Job) -> Result<()> {
        self.tx
            .lock()
            .unwrap()
            .send(job)
            .map_err(|_| anyhow!("engine executor thread is gone"))
    }

    /// Run main-branch stages `from..=to` (1-based, inclusive) on a
    /// batched activation tensor whose leading dim must be an exported
    /// batch size.
    pub fn run_stages(&self, from: usize, to: usize, input: &HostTensor) -> Result<HostTensor> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::RunStages {
            from,
            to,
            input: input.clone(),
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Full main-branch forward via the monolithic artifact (cloud-only
    /// fast path + the stage-vs-monolith fusion ablation).
    pub fn run_full(&self, input: &HostTensor) -> Result<HostTensor> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::RunFull {
            input: input.clone(),
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Evaluate the side branch on stage-`after_stage` activations.
    pub fn run_branch(&self, activations: &HostTensor) -> Result<BranchOutput> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::RunBranch {
            input: activations.clone(),
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Precompile all artifacts of this flavor; returns compile seconds.
    pub fn warmup(&self) -> Result<f64> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::Warmup { reply })?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    pub fn cached_count(&self) -> usize {
        let (reply, rx) = mpsc::channel();
        if self.send(Job::CachedCount { reply }).is_err() {
            return 0;
        }
        rx.recv().unwrap_or(0)
    }

    /// Largest exported batch size (the executable the batcher fills).
    pub fn max_batch(&self) -> usize {
        *self.manifest.batch_sizes.iter().max().unwrap()
    }

    /// Argmax class per sample of a (B, C) probability/logit tensor.
    pub fn argmax_classes(probs: &HostTensor) -> Vec<usize> {
        (0..probs.batch())
            .map(|i| {
                // First maximum wins ties (deterministic, matches numpy).
                let row = probs.sample(i);
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate().skip(1) {
                    if v > row[best] {
                        best = j;
                    }
                }
                best
            })
            .collect()
    }
}

fn executor_loop(
    store: ArtifactStore,
    manifest: Manifest,
    flavor: Flavor,
    rx: mpsc::Receiver<Job>,
) {
    let check_batch = |n: usize| -> Result<()> {
        if !manifest.batch_sizes.contains(&n) {
            bail!(
                "batch size {n} not exported (have {:?})",
                manifest.batch_sizes
            );
        }
        Ok(())
    };

    while let Ok(job) = rx.recv() {
        match job {
            Job::RunStages {
                from,
                to,
                input,
                reply,
            } => {
                let result = (|| -> Result<HostTensor> {
                    let n = manifest.num_stages();
                    if from < 1 || to > n || from > to {
                        bail!("invalid stage range {from}..={to} (1..={n})");
                    }
                    check_batch(input.batch())?;
                    let mut x = input;
                    for i in from..=to {
                        let stage = &manifest.stages[i - 1];
                        let exe = store.get(stage.artifact(flavor, x.batch())?)?;
                        x = exe.run1(&x)?;
                    }
                    Ok(x)
                })();
                let _ = reply.send(result);
            }
            Job::RunFull { input, reply } => {
                let result = (|| -> Result<HostTensor> {
                    check_batch(input.batch())?;
                    let exe = store.get(manifest.full_artifact(flavor, input.batch())?)?;
                    exe.run1(&input)
                })();
                let _ = reply.send(result);
            }
            Job::RunBranch { input, reply } => {
                let result = (|| -> Result<BranchOutput> {
                    check_batch(input.batch())?;
                    let exe =
                        store.get(manifest.branch.artifact(flavor, input.batch())?)?;
                    let (probs, ent) = exe.run2(&input)?;
                    Ok(BranchOutput {
                        entropy: ent.data().to_vec(),
                        probs,
                    })
                })();
                let _ = reply.send(result);
            }
            Job::Warmup { reply } => {
                let result = (|| -> Result<f64> {
                    let mut total =
                        store.warmup(&manifest, flavor, &manifest.batch_sizes)?;
                    for &b in &manifest.batch_sizes {
                        if let Ok(name) = manifest.full_artifact(flavor, b) {
                            total += store.get(name)?.compile_time_s;
                        }
                    }
                    Ok(total)
                })();
                let _ = reply.send(result);
            }
            Job::CachedCount { reply } => {
                let _ = reply.send(store.cached_count());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_rows() {
        let t = HostTensor::new(vec![3, 2], vec![0.9, 0.1, 0.2, 0.8, 0.5, 0.5]).unwrap();
        assert_eq!(InferenceEngine::argmax_classes(&t), vec![0, 1, 0]);
    }
}
