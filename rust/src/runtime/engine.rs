//! Inference engine: a Send + Sync handle to a dedicated executor thread
//! that owns the (non-Send) compute backend.
//!
//! With `feature = "xla-pjrt"` the backend is a PJRT client + artifact
//! cache (the `xla` crate's `PjRtClient` is `Rc`-based and must stay on
//! one thread); without it the backend is the pure-Rust [`super::sim::SimNet`].
//! Either way the serving coordinator, TCP connections and benches call
//! the engine from many threads over a channel. This also mirrors the
//! paper's deployment: the *edge device* and the *cloud server* are
//! separate compute resources — the coordinator gives each node its own
//! engine (its own executor), so edge and cloud stages execute
//! concurrently like the real pipeline.
//!
//! `run_stages(a..=b)` composes per-stage executables to realize any
//! partition; `run_branch` evaluates the side branch's fused
//! (probs, entropy) head.

use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::config::settings::Flavor;
use crate::model::Manifest;

#[cfg(feature = "xla-pjrt")]
use super::artifact::ArtifactStore;
use super::sim::SimNet;
use super::tensor::HostTensor;

/// Output of a branch evaluation for one batch.
#[derive(Debug, Clone)]
pub struct BranchOutput {
    /// (B, num_classes) class probabilities.
    pub probs: HostTensor,
    /// (B,) entropy in nats.
    pub entropy: Vec<f32>,
}

/// The executor thread's compute implementation.
enum Backend {
    #[cfg(feature = "xla-pjrt")]
    Pjrt(ArtifactStore),
    Sim(SimNet),
}

enum Job {
    RunStages {
        from: usize,
        to: usize,
        input: HostTensor,
        reply: mpsc::Sender<Result<HostTensor>>,
    },
    RunFull {
        input: HostTensor,
        reply: mpsc::Sender<Result<HostTensor>>,
    },
    RunBranch {
        input: HostTensor,
        reply: mpsc::Sender<Result<BranchOutput>>,
    },
    Warmup {
        reply: mpsc::Sender<Result<f64>>,
    },
    CachedCount {
        reply: mpsc::Sender<usize>,
    },
}

#[derive(Clone)]
pub struct InferenceEngine {
    tx: Arc<Mutex<mpsc::Sender<Job>>>,
    manifest: Arc<Manifest>,
    flavor: Flavor,
}

impl InferenceEngine {
    /// Spawn a PJRT-backed engine (executor thread creates its own PJRT
    /// CPU client rooted at the artifacts dir). `name` labels the thread
    /// ("edge", "cloud"). Requires `feature = "xla-pjrt"`; without it
    /// this errors — use [`InferenceEngine::open_sim`] instead.
    #[cfg(feature = "xla-pjrt")]
    pub fn open(
        dir: &std::path::Path,
        manifest: Manifest,
        flavor: Flavor,
        name: &str,
    ) -> Result<InferenceEngine> {
        let dir = dir.to_path_buf();
        Self::spawn_with_backend(manifest, flavor, name, move || {
            Ok(Backend::Pjrt(ArtifactStore::open(&dir)?))
        })
    }

    /// PJRT-less build: opening on-disk artifacts is impossible — error
    /// with a pointer at the simulated backend instead.
    #[cfg(not(feature = "xla-pjrt"))]
    pub fn open(
        dir: &std::path::Path,
        manifest: Manifest,
        flavor: Flavor,
        name: &str,
    ) -> Result<InferenceEngine> {
        let _ = (dir, manifest, flavor, name);
        bail!(
            "this build has no PJRT backend (feature `xla-pjrt` disabled); \
             use InferenceEngine::open_sim for the simulated runtime"
        )
    }

    /// Spawn an engine backed by the deterministic simulated runtime
    /// (always available; no artifacts on disk). Pair with
    /// [`Manifest::synthetic_sim`].
    pub fn open_sim(manifest: Manifest, name: &str) -> Result<InferenceEngine> {
        Self::open_sim_with_cost(manifest, name, Duration::ZERO)
    }

    /// [`InferenceEngine::open_sim`] with a synthetic per-stage compute
    /// cost, so throughput/scaling experiments have something to amortize.
    pub fn open_sim_with_cost(
        manifest: Manifest,
        name: &str,
        stage_cost: Duration,
    ) -> Result<InferenceEngine> {
        let sim_manifest = manifest.clone();
        Self::spawn_with_backend(manifest, Flavor::Ref, name, move || {
            Ok(Backend::Sim(SimNet::with_stage_cost(
                sim_manifest,
                stage_cost,
            )))
        })
    }

    fn spawn_with_backend(
        manifest: Manifest,
        flavor: Flavor,
        name: &str,
        make: impl FnOnce() -> Result<Backend> + Send + 'static,
    ) -> Result<InferenceEngine> {
        let (tx, rx) = mpsc::channel::<Job>();
        let worker_manifest = manifest.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name(format!("engine-{name}"))
            .spawn(move || {
                let backend = match make() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                executor_loop(backend, worker_manifest, flavor, rx);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))??;
        Ok(InferenceEngine {
            tx: Arc::new(Mutex::new(tx)),
            manifest: Arc::new(manifest),
            flavor,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn flavor(&self) -> Flavor {
        self.flavor
    }

    fn send(&self, job: Job) -> Result<()> {
        self.tx
            .lock()
            .unwrap()
            .send(job)
            .map_err(|_| anyhow!("engine executor thread is gone"))
    }

    /// Run main-branch stages `from..=to` (1-based, inclusive) on a
    /// batched activation tensor whose leading dim must be an exported
    /// batch size.
    pub fn run_stages(&self, from: usize, to: usize, input: &HostTensor) -> Result<HostTensor> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::RunStages {
            from,
            to,
            input: input.clone(),
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Full main-branch forward via the monolithic artifact (cloud-only
    /// fast path + the stage-vs-monolith fusion ablation).
    pub fn run_full(&self, input: &HostTensor) -> Result<HostTensor> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::RunFull {
            input: input.clone(),
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Evaluate the side branch on stage-`after_stage` activations.
    pub fn run_branch(&self, activations: &HostTensor) -> Result<BranchOutput> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::RunBranch {
            input: activations.clone(),
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Precompile all artifacts of this flavor; returns compile seconds
    /// (0 on the simulated backend — nothing to compile).
    pub fn warmup(&self) -> Result<f64> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::Warmup { reply })?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    pub fn cached_count(&self) -> usize {
        let (reply, rx) = mpsc::channel();
        if self.send(Job::CachedCount { reply }).is_err() {
            return 0;
        }
        rx.recv().unwrap_or(0)
    }

    /// Largest exported batch size (the executable the batcher fills).
    pub fn max_batch(&self) -> usize {
        *self.manifest.batch_sizes.iter().max().unwrap()
    }

    /// Smallest exported batch size that fits `n` samples (or the
    /// largest exported size when `n` exceeds every export — callers
    /// chunk to [`InferenceEngine::max_batch`] first). The bucket a
    /// caller pads a partial batch up to before executing.
    pub fn bucket_batch(&self, n: usize) -> usize {
        self.manifest
            .batch_sizes
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .unwrap_or_else(|| self.max_batch())
    }

    /// The shared cloud-suffix path: pad a batched activation of `n`
    /// real samples to an exported batch size — chunking to
    /// [`InferenceEngine::max_batch`] first when `n` exceeds every
    /// export — run stages `from..=N`, and return one argmax class per
    /// (unpadded) sample. Used by both the in-process cloud worker and
    /// the remote cloud-stage server so the two execution paths cannot
    /// drift (an oversized group must chunk, not panic, on either).
    pub fn run_suffix_classes(
        &self,
        from: usize,
        stacked: &HostTensor,
        n: usize,
    ) -> Result<Vec<usize>> {
        let max_exec = self.max_batch();
        if n <= max_exec {
            let x = stacked.pad_batch(self.bucket_batch(n));
            let out = self.run_stages(from, self.manifest.num_stages(), &x)?;
            let mut classes = Self::argmax_classes(&out);
            classes.truncate(n);
            return Ok(classes);
        }
        let samples = stacked.unstack();
        let mut classes = Vec::with_capacity(n);
        for chunk in samples.chunks(max_exec) {
            let restacked = HostTensor::stack(chunk)?;
            classes.extend(self.run_suffix_classes(from, &restacked, chunk.len())?);
        }
        Ok(classes)
    }

    /// The mid-chain sibling of [`InferenceEngine::run_suffix_classes`]:
    /// pad a batched activation of `n` real samples to an exported
    /// batch size — chunking to [`InferenceEngine::max_batch`] first
    /// when `n` exceeds every export — run stages `from..=to`, and
    /// return the resulting activations truncated back to `n` samples.
    /// Used by the forwarding cloud-stage server, which executes a
    /// middle segment of the partition chain and ships the output
    /// onward instead of reducing to classes.
    pub fn run_segment_acts(
        &self,
        from: usize,
        to: usize,
        stacked: &HostTensor,
        n: usize,
    ) -> Result<HostTensor> {
        let max_exec = self.max_batch();
        if n <= max_exec {
            let x = stacked.pad_batch(self.bucket_batch(n));
            let out = self.run_stages(from, to, &x)?;
            return Ok(out.take_batch(n));
        }
        let samples = stacked.unstack();
        let mut outs = Vec::with_capacity(n);
        for chunk in samples.chunks(max_exec) {
            let restacked = HostTensor::stack(chunk)?;
            outs.extend(
                self.run_segment_acts(from, to, &restacked, chunk.len())?
                    .unstack(),
            );
        }
        HostTensor::stack(&outs)
    }

    /// Argmax class per sample of a (B, C) probability/logit tensor.
    pub fn argmax_classes(probs: &HostTensor) -> Vec<usize> {
        (0..probs.batch())
            .map(|i| {
                // First maximum wins ties (deterministic, matches numpy).
                let row = probs.sample(i);
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate().skip(1) {
                    if v > row[best] {
                        best = j;
                    }
                }
                best
            })
            .collect()
    }
}

#[cfg_attr(not(feature = "xla-pjrt"), allow(unused_variables))]
fn backend_run_stages(
    backend: &Backend,
    manifest: &Manifest,
    flavor: Flavor,
    from: usize,
    to: usize,
    input: HostTensor,
) -> Result<HostTensor> {
    let n = manifest.num_stages();
    if from < 1 || to > n || from > to {
        bail!("invalid stage range {from}..={to} (1..={n})");
    }
    match backend {
        #[cfg(feature = "xla-pjrt")]
        Backend::Pjrt(store) => {
            let mut x = input;
            for i in from..=to {
                let stage = &manifest.stages[i - 1];
                let exe = store.get(stage.artifact(flavor, x.batch())?)?;
                x = exe.run1(&x)?;
            }
            Ok(x)
        }
        Backend::Sim(sim) => sim.run_stages(from, to, &input),
    }
}

#[cfg_attr(not(feature = "xla-pjrt"), allow(unused_variables))]
fn backend_run_full(
    backend: &Backend,
    manifest: &Manifest,
    flavor: Flavor,
    input: HostTensor,
) -> Result<HostTensor> {
    match backend {
        #[cfg(feature = "xla-pjrt")]
        Backend::Pjrt(store) => {
            let exe = store.get(manifest.full_artifact(flavor, input.batch())?)?;
            exe.run1(&input)
        }
        Backend::Sim(sim) => sim.run_full(&input),
    }
}

#[cfg_attr(not(feature = "xla-pjrt"), allow(unused_variables))]
fn backend_run_branch(
    backend: &Backend,
    manifest: &Manifest,
    flavor: Flavor,
    input: HostTensor,
) -> Result<BranchOutput> {
    match backend {
        #[cfg(feature = "xla-pjrt")]
        Backend::Pjrt(store) => {
            let exe = store.get(manifest.branch.artifact(flavor, input.batch())?)?;
            let (probs, ent) = exe.run2(&input)?;
            Ok(BranchOutput {
                entropy: ent.data().to_vec(),
                probs,
            })
        }
        Backend::Sim(sim) => sim.run_branch(&input),
    }
}

#[cfg_attr(not(feature = "xla-pjrt"), allow(unused_variables))]
fn backend_warmup(backend: &Backend, manifest: &Manifest, flavor: Flavor) -> Result<f64> {
    match backend {
        #[cfg(feature = "xla-pjrt")]
        Backend::Pjrt(store) => {
            let mut total = store.warmup(manifest, flavor, &manifest.batch_sizes)?;
            for &b in &manifest.batch_sizes {
                if let Ok(name) = manifest.full_artifact(flavor, b) {
                    total += store.get(name)?.compile_time_s;
                }
            }
            Ok(total)
        }
        Backend::Sim(_) => Ok(0.0),
    }
}

fn backend_cached_count(backend: &Backend, manifest: &Manifest) -> usize {
    match backend {
        #[cfg(feature = "xla-pjrt")]
        Backend::Pjrt(store) => store.cached_count(),
        // Everything the sim "compiles" is always resident.
        Backend::Sim(_) => manifest.num_stages() + 1,
    }
}

fn executor_loop(backend: Backend, manifest: Manifest, flavor: Flavor, rx: mpsc::Receiver<Job>) {
    let check_batch = |n: usize| -> Result<()> {
        if !manifest.batch_sizes.contains(&n) {
            bail!(
                "batch size {n} not exported (have {:?})",
                manifest.batch_sizes
            );
        }
        Ok(())
    };

    while let Ok(job) = rx.recv() {
        match job {
            Job::RunStages {
                from,
                to,
                input,
                reply,
            } => {
                let result = check_batch(input.batch())
                    .and_then(|()| backend_run_stages(&backend, &manifest, flavor, from, to, input));
                let _ = reply.send(result);
            }
            Job::RunFull { input, reply } => {
                let result = check_batch(input.batch())
                    .and_then(|()| backend_run_full(&backend, &manifest, flavor, input));
                let _ = reply.send(result);
            }
            Job::RunBranch { input, reply } => {
                let result = check_batch(input.batch())
                    .and_then(|()| backend_run_branch(&backend, &manifest, flavor, input));
                let _ = reply.send(result);
            }
            Job::Warmup { reply } => {
                let _ = reply.send(backend_warmup(&backend, &manifest, flavor));
            }
            Job::CachedCount { reply } => {
                let _ = reply.send(backend_cached_count(&backend, &manifest));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_rows() {
        let t = HostTensor::new(vec![3, 2], vec![0.9, 0.1, 0.2, 0.8, 0.5, 0.5]).unwrap();
        assert_eq!(InferenceEngine::argmax_classes(&t), vec![0, 1, 0]);
    }

    #[test]
    fn sim_engine_end_to_end() {
        let manifest =
            Manifest::synthetic_sim("sim-e", vec![4], &[8, 2], 1, 2, vec![1, 2]).unwrap();
        let engine = InferenceEngine::open_sim(manifest, "test").unwrap();
        assert_eq!(engine.warmup().unwrap(), 0.0);
        assert_eq!(engine.cached_count(), 3);
        assert_eq!(engine.max_batch(), 2);
        assert_eq!(engine.bucket_batch(1), 1);
        assert_eq!(engine.bucket_batch(2), 2);
        // Beyond every export: callers chunk to max_batch first.
        assert_eq!(engine.bucket_batch(3), 2);

        let x = HostTensor::new(vec![2, 4], vec![0.1, 0.9, 0.2, 0.8, 0.5, 0.5, 0.5, 0.5]).unwrap();
        let acts = engine.run_stages(1, 1, &x).unwrap();
        assert_eq!(acts.shape(), &[2, 8]);
        let out = engine.run_stages(2, 2, &acts).unwrap();
        assert_eq!(out.shape(), &[2, 2]);
        assert_eq!(engine.run_full(&x).unwrap(), out);
        let branch = engine.run_branch(&acts).unwrap();
        assert_eq!(branch.entropy.len(), 2);

        // Unexported batch size rejected before the backend runs.
        let bad = HostTensor::zeros(vec![3, 4]);
        assert!(engine.run_stages(1, 1, &bad).is_err());

        // Shared cloud-suffix path: an oversized group (3 > max export
        // 2) chunks instead of panicking, and each sample's class
        // matches a singleton run.
        let b3 =
            HostTensor::new(vec![3, 4], (0..12).map(|i| i as f32 * 0.1).collect()).unwrap();
        let classes = engine.run_suffix_classes(1, &b3, 3).unwrap();
        assert_eq!(classes.len(), 3);
        for (i, t) in b3.unstack().iter().enumerate() {
            let one = HostTensor::stack(std::slice::from_ref(t)).unwrap();
            let out = engine.run_stages(1, 2, &one).unwrap();
            assert_eq!(classes[i], InferenceEngine::argmax_classes(&out)[0]);
        }

        // Mid-chain segment path: same pad/chunk handling, but the
        // activations come back (truncated to the real batch) instead
        // of classes.
        let seg = engine.run_segment_acts(1, 1, &b3, 3).unwrap();
        assert_eq!(seg.shape(), &[3, 8]);
        for (i, t) in b3.unstack().iter().enumerate() {
            let one = HostTensor::stack(std::slice::from_ref(t)).unwrap();
            let acts = engine.run_stages(1, 1, &one).unwrap();
            assert_eq!(seg.sample(i), acts.sample(0));
        }
    }
}
