//! Simulated compute backend: a deterministic pure-Rust BranchyNet that
//! honors the manifest contract (stage shape chain, batched execution,
//! a branch head producing (probs, entropy)) without artifacts or XLA.
//!
//! The arithmetic is not a neural network — it is a cheap deterministic
//! transform that propagates two per-sample statistics (mean level and
//! high-frequency energy, the feature separating the synthetic workload's
//! two classes) so downstream behavior is data-dependent the way the real
//! model's is: the branch's entropy varies per sample, extreme entropy
//! thresholds exit everything/nothing, and stage outputs always match the
//! manifest's declared shapes.
//!
//! An optional per-stage compute cost (implemented as a sleep, so it
//! scales with pipeline parallelism rather than with host core count)
//! makes throughput experiments on the sharded fleet meaningful.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::model::Manifest;

use super::engine::BranchOutput;
use super::tensor::HostTensor;

/// Sigmoid sharpness of the simulated branch head.
const SIM_SCALE: f32 = 2.0;
/// High-frequency-energy pivot separating the two synthetic classes.
const SIM_PIVOT: f32 = 0.5;

/// Deterministic [0, 1) weight for (stage, element) pairs.
fn hash01(a: u64, b: u64) -> f32 {
    let mut s = a
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(b.wrapping_mul(0x243F_6A88_85A3_08D3));
    let z = crate::util::rng::splitmix64(&mut s);
    (z >> 40) as f32 / (1u64 << 24) as f32
}

/// (mean, mean |x[j+1] - x[j]|) of one sample's elements.
fn features(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let m = xs.iter().sum::<f32>() / xs.len() as f32;
    let hf = if xs.len() < 2 {
        0.0
    } else {
        xs.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f32>() / (xs.len() - 1) as f32
    };
    (m, hf)
}

fn class_logits(hf: f32, num_classes: usize) -> Vec<f32> {
    let score = (SIM_SCALE * (hf - SIM_PIVOT)).clamp(-10.0, 10.0);
    (0..num_classes)
        .map(|c| match c {
            0 => -0.5 * score,
            1 => 0.5 * score,
            _ => -3.0,
        })
        .collect()
}

fn softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| (l - max).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

fn entropy_nats(p: &[f32]) -> f32 {
    -p.iter()
        .filter(|&&v| v > 0.0)
        .map(|&v| v * v.ln())
        .sum::<f32>()
}

/// The simulated model. `Send` so the engine's executor thread can own it.
#[derive(Debug, Clone)]
pub struct SimNet {
    manifest: Manifest,
    /// Synthetic compute cost charged per stage invocation (per batch,
    /// like a real accelerator amortizes over the batch).
    stage_cost: Duration,
}

impl SimNet {
    pub fn new(manifest: Manifest) -> SimNet {
        SimNet::with_stage_cost(manifest, Duration::ZERO)
    }

    pub fn with_stage_cost(manifest: Manifest, stage_cost: Duration) -> SimNet {
        SimNet {
            manifest,
            stage_cost,
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn charge_stage_cost(&self) {
        if !self.stage_cost.is_zero() {
            std::thread::sleep(self.stage_cost);
        }
    }

    /// Main-branch stages `from..=to` (1-based, inclusive) on a batched
    /// activation tensor.
    pub fn run_stages(&self, from: usize, to: usize, input: &HostTensor) -> Result<HostTensor> {
        let n = self.manifest.num_stages();
        if from < 1 || to > n || from > to {
            bail!("invalid stage range {from}..={to} (1..={n})");
        }
        let mut x = input.clone();
        for i in from..=to {
            let stage = &self.manifest.stages[i - 1];
            if x.shape()[1..] != stage.in_shape[..] {
                bail!(
                    "stage {} expects per-sample shape {:?}, got {:?}",
                    stage.name,
                    stage.in_shape,
                    &x.shape()[1..]
                );
            }
            x = self.stage_forward(i, &x, &stage.out_shape);
            self.charge_stage_cost();
        }
        Ok(x)
    }

    /// Full main-branch forward (the monolithic-artifact fast path).
    pub fn run_full(&self, input: &HostTensor) -> Result<HostTensor> {
        self.run_stages(1, self.manifest.num_stages(), input)
    }

    /// Branch head on activations at the branch's attach point.
    pub fn run_branch(&self, activations: &HostTensor) -> Result<BranchOutput> {
        let want = &self.manifest.branch.in_shape;
        if activations.shape()[1..] != want[..] {
            bail!(
                "branch {} expects per-sample shape {:?}, got {:?}",
                self.manifest.branch.name,
                want,
                &activations.shape()[1..]
            );
        }
        let b = activations.batch();
        let c = self.manifest.num_classes;
        let mut probs = Vec::with_capacity(b * c);
        let mut entropy = Vec::with_capacity(b);
        for s in 0..b {
            let (_, hf) = features(activations.sample(s));
            let p = softmax(&class_logits(hf, c));
            entropy.push(entropy_nats(&p));
            probs.extend(p);
        }
        self.charge_stage_cost();
        Ok(BranchOutput {
            probs: HostTensor::new(vec![b, c], probs)?,
            entropy,
        })
    }

    fn stage_forward(&self, stage_idx: usize, x: &HostTensor, out_shape: &[usize]) -> HostTensor {
        let b = x.batch();
        let k_out: usize = out_shape.iter().product();
        // The final stage emits class logits so edge-only/cloud-tail
        // argmax behaves like a classifier head.
        let is_head =
            stage_idx == self.manifest.num_stages() && k_out == self.manifest.num_classes;
        let mut data = Vec::with_capacity(b * k_out);
        for s in 0..b {
            let xs = x.sample(s);
            let (m, hf) = features(xs);
            if is_head {
                data.extend(class_logits(hf, self.manifest.num_classes));
            } else {
                for k in 0..k_out {
                    let w = hash01(stage_idx as u64, k as u64);
                    let sign = if k % 2 == 0 { 1.0 } else { -1.0 };
                    let carry = if xs.is_empty() { 0.0 } else { xs[k % xs.len()] };
                    // Mean rides along; HF energy is re-encoded as the
                    // amplitude of an alternating ripple so it survives
                    // every stage; a strided carry keeps raw data mixed in.
                    data.push(0.6 * m + 0.2 + hf * sign * (0.8 + 0.4 * w) + 0.05 * carry);
                }
            }
        }
        let mut shape = vec![b];
        shape.extend_from_slice(out_shape);
        HostTensor::new(shape, data).expect("sim output length matches declared shape")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn manifest() -> Manifest {
        Manifest::synthetic_sim(
            "sim-test",
            vec![3, 8, 8],
            &[64, 32, 2],
            1,
            2,
            vec![1, 2, 4],
        )
        .unwrap()
    }

    fn input(b: usize, seed: f32) -> HostTensor {
        let n = 3 * 8 * 8;
        let data: Vec<f32> = (0..b * n)
            .map(|i| ((i as f32 * 0.37 + seed).sin()) * 0.5)
            .collect();
        HostTensor::new(vec![b, 3, 8, 8], data).unwrap()
    }

    #[test]
    fn deterministic_and_shape_correct() {
        let sim = SimNet::new(manifest());
        let x = input(2, 1.0);
        let a = sim.run_stages(1, 3, &x).unwrap();
        let b = sim.run_stages(1, 3, &x).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.shape(), &[2, 2]); // final stage: class logits
        let mid = sim.run_stages(1, 2, &x).unwrap();
        assert_eq!(mid.shape(), &[2, 32]);
        assert_eq!(sim.run_full(&x).unwrap(), a);
    }

    #[test]
    fn stage_chain_composes() {
        let sim = SimNet::new(manifest());
        let x = input(1, 2.0);
        let direct = sim.run_stages(1, 3, &x).unwrap();
        let a = sim.run_stages(1, 1, &x).unwrap();
        let b = sim.run_stages(2, 3, &a).unwrap();
        assert_eq!(direct, b);
    }

    #[test]
    fn branch_entropy_strictly_inside_binary_range() {
        let sim = SimNet::new(manifest());
        let acts = sim.run_stages(1, 1, &input(4, 3.0)).unwrap();
        let out = sim.run_branch(&acts).unwrap();
        assert_eq!(out.probs.shape(), &[4, 2]);
        assert_eq!(out.entropy.len(), 4);
        for &e in &out.entropy {
            assert!(e > 0.0 && e < 0.6932, "entropy {e} outside (0, ln 2)");
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let sim = SimNet::new(manifest());
        let bad = HostTensor::zeros(vec![1, 5]);
        assert!(sim.run_stages(1, 1, &bad).is_err());
        assert!(sim.run_branch(&bad).is_err());
        assert!(sim.run_stages(0, 1, &input(1, 0.0)).is_err());
        assert!(sim.run_stages(1, 9, &input(1, 0.0)).is_err());
    }

    #[test]
    fn stage_cost_is_charged_per_stage() {
        let sim = SimNet::with_stage_cost(manifest(), Duration::from_millis(5));
        let t0 = Instant::now();
        sim.run_stages(1, 3, &input(1, 0.0)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }
}
