//! Planner replanning throughput: cold full solve (graph construction +
//! Dijkstra per call) vs the planner's precomputed O(N) sweep vs the
//! log-bucketed plan cache, driven by a random-walk bandwidth trace —
//! i.e. "replans per second" as the adaptive loop would experience it.
//! This is the perf baseline for the planner subsystem; the acceptance
//! bar is cached/incremental replanning ≥ 10× faster than the cold
//! full-solve path.
//!
//!     cargo bench --bench planner

use std::time::Duration;

use branchyserve::harness::{bench, print_table, BenchResult};
use branchyserve::model::synthetic;
use branchyserve::network::bandwidth::LinkModel;
use branchyserve::network::BandwidthTrace;
use branchyserve::partition::compact;
use branchyserve::planner::Planner;
use branchyserve::util::timefmt::format_rate;

fn main() {
    branchyserve::util::logger::init();
    // SMOKE=1 (CI): shorter timing windows, same assertions.
    let window = if std::env::var("SMOKE").is_ok() {
        Duration::from_millis(60)
    } else {
        Duration::from_millis(200)
    };

    // The bandwidth samples an adaptive loop would see: a multiplicative
    // random walk around 4G, clamped to [0.2, 50] Mbps.
    let trace = BandwidthTrace::random_walk(5.85, 0.1, 4096, 0.2, 50.0, 9);
    let links: Vec<LinkModel> = trace
        .points()
        .iter()
        .map(|&(_, mbps)| LinkModel::new(mbps, 0.0))
        .collect();

    let mut rows: Vec<BenchResult> = Vec::new();
    let mut ratios: Vec<(usize, f64, f64)> = Vec::new();

    for &n in &[64usize, 256, 1024, 4096] {
        let (desc, profile) = synthetic::deep_chain(n, 8, 0.3, 42);

        // Cold: rebuild the solver inputs (compact graph) and run
        // Dijkstra for every bandwidth sample — the pre-planner shape
        // of `solver::solve(.., paper_mode = false)`, i.e. serving mode
        // (include_branch_cost = true) to match the planner rows below.
        let mut ic = {
            let mut i = 0usize;
            move || {
                i = (i + 1) % 4096;
                i
            }
        };
        let cold = bench(
            &format!("cold graph+dijkstra  n={n}"),
            window,
            || {
                let link = links[ic()];
                let (split, _) = compact::solve_split(&desc, &profile, link, 1e-9, true);
                std::hint::black_box(split);
            },
        );

        // Incremental: one precompute, O(N) sweep per sample.
        let planner = Planner::new(&desc, &profile, 1e-9, false);
        let mut ii = {
            let mut i = 0usize;
            move || {
                i = (i + 1) % 4096;
                i
            }
        };
        let incremental = bench(
            &format!("planner plan_for     n={n}"),
            window,
            || {
                let link = links[ii()];
                let plan = planner.plan_for(link);
                std::hint::black_box(plan.split_after);
            },
        );

        // Cached: bucket lookups after the first pass over the trace.
        for &link in &links {
            let _ = planner.plan_cached(link); // warm the buckets
        }
        let mut ik = {
            let mut i = 0usize;
            move || {
                i = (i + 1) % 4096;
                i
            }
        };
        let cached = bench(
            &format!("planner plan_cached  n={n}"),
            window,
            || {
                let link = links[ik()];
                let plan = planner.plan_cached(link);
                std::hint::black_box(plan.split_after);
            },
        );

        ratios.push((
            n,
            cold.mean_s / incremental.mean_s,
            cold.mean_s / cached.mean_s,
        ));
        rows.push(cold);
        rows.push(incremental);
        rows.push(cached);
        let (hits, misses) = planner.cache_stats();
        println!(
            "n={n}: plan cache {hits} hits / {misses} misses over the trace \
             ({} distinct buckets)",
            misses
        );
    }
    print_table("replanning across a random-walk bandwidth trace", &rows);

    println!("\n=== replans/sec (trace-driven) ===");
    for (row, &(n, r_inc, r_cached)) in rows.chunks(3).zip(&ratios) {
        println!(
            "n={n:<5} cold {:>12}  incremental {:>12} ({r_inc:6.1}x)  cached {:>12} ({r_cached:6.1}x)",
            format_rate(1.0 / row[0].mean_s),
            format_rate(1.0 / row[1].mean_s),
            format_rate(1.0 / row[2].mean_s),
        );
    }

    // Acceptance bar: at production-ish depth the precomputed sweep and
    // the cache must both beat the cold path by >= 10x.
    let &(n, r_inc, r_cached) = ratios
        .iter()
        .find(|&&(n, _, _)| n == 1024)
        .expect("n=1024 measured");
    assert!(
        r_inc >= 10.0,
        "incremental replanning only {r_inc:.1}x faster than cold at n={n}"
    );
    assert!(
        r_cached >= 10.0,
        "cached replanning only {r_cached:.1}x faster than cold at n={n}"
    );
    println!("\nplanner >= 10x cold-solve at n=1024: OK ({r_inc:.1}x / {r_cached:.1}x)");
}
