//! Fleet throughput scaling: aggregate completions/sec as the edge shard
//! count grows 1 → 2 → 4 on the simulated model. The synthetic per-stage
//! compute cost is sleep-based, so the scaling signal measures pipeline
//! parallelism (what sharding buys) rather than host core count.
//!
//!     cargo bench --bench fleet          # full run
//!     SMOKE=1 cargo bench --bench fleet  # CI smoke: shorter windows
//!
//! Acceptance bar: throughput must increase monotonically from 1 to 4
//! shards (each doubling at least +20%).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use branchyserve::fleet::{ClassProfile, ClassRegistry, Fleet, FleetConfig, RoutePolicy};
use branchyserve::model::Manifest;
use branchyserve::runtime::InferenceEngine;
use branchyserve::timing::DelayProfile;
use branchyserve::util::timefmt::format_rate;
use branchyserve::workload::ImageSource;

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let smoke = std::env::var("SMOKE").is_ok();
    let stage_cost = Duration::from_micros(300);
    let window = Duration::from_millis(if smoke { 500 } else { 1500 });

    // Output sizes chosen so every cut's transfer dwarfs the remaining
    // edge work on a 3G uplink: the plan is edge-only and shard scaling
    // measures pure edge-pipeline parallelism.
    let manifest = Manifest::synthetic_sim(
        "sim-fleet-bench",
        vec![3, 32, 32],
        &[4096, 2048, 1024, 2],
        1,
        2,
        vec![1, 2, 4, 8],
    )?;
    let profile = DelayProfile::from_cloud_times(vec![2e-4; 4], 5e-5, 20.0);

    let mut rows: Vec<(usize, u64, f64, Vec<u64>)> = Vec::new();
    for &shards in &[1usize, 2, 4] {
        let m = manifest.clone();
        let fleet = Arc::new(Fleet::start(
            ClassRegistry::single(ClassProfile::custom("3g", 1.10, 0.0)?),
            &manifest,
            &profile,
            FleetConfig {
                shards_per_class: shards,
                cloud_workers_per_shard: 2,
                // Deterministic spread: this bench gates CI, and
                // round-robin removes any routing luck from the signal.
                routing: RoutePolicy::RoundRobin,
                entropy_threshold: 0.0, // nothing exits: full pipeline work
                batch_timeout: Duration::from_millis(1),
                real_time_channel: false,
                ..Default::default()
            },
            move |label| {
                Ok((
                    InferenceEngine::open_sim_with_cost(
                        m.clone(),
                        &format!("{label}-e"),
                        stage_cost,
                    )?,
                    InferenceEngine::open_sim_with_cost(
                        m.clone(),
                        &format!("{label}-c"),
                        stage_cost,
                    )?,
                ))
            },
        )?);
        let plan = fleet.plan_of(fleet.class_by_name("3g").unwrap())?;
        assert!(
            plan.is_edge_only(4),
            "bench premise broken: expected an edge-only plan, got split {}",
            plan.split_after
        );

        // Closed loop: 8 clients per shard keep every batcher saturated.
        let completed = Arc::new(AtomicU64::new(0));
        let start = Instant::now();
        let deadline = start + window;
        let clients: Vec<_> = (0..8 * shards)
            .map(|c| {
                let fleet = fleet.clone();
                let completed = completed.clone();
                std::thread::spawn(move || {
                    let class = fleet.class_by_name("3g").unwrap();
                    let (img, _) = ImageSource::new(900 + c as u64).sample();
                    while Instant::now() < deadline {
                        if fleet.infer_sync(class, img.clone()).is_ok() {
                            completed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in clients {
            h.join().expect("client thread");
        }
        let wall = start.elapsed().as_secs_f64();
        let report = fleet.shutdown();
        let done = completed.load(Ordering::Relaxed);
        let per_shard: Vec<u64> = report.classes[0].shards.iter().map(|s| s.completed).collect();
        rows.push((shards, done, done as f64 / wall, per_shard));
    }

    println!("\n=== fleet throughput scaling (sim model, 3G class, edge-only plan) ===");
    println!("{:>7} {:>12} {:>14}  per-shard completions", "shards", "completed", "throughput");
    for (shards, done, tput, per_shard) in &rows {
        println!(
            "{shards:>7} {done:>12} {:>14}  {per_shard:?}",
            format_rate(*tput)
        );
    }

    // Monotonic scaling 1 -> 2 -> 4 with a real margin at each doubling.
    for pair in rows.windows(2) {
        let (s0, _, t0, _) = &pair[0];
        let (s1, _, t1, _) = &pair[1];
        assert!(
            t1 > &(t0 * 1.2),
            "throughput did not scale {s0} -> {s1} shards: {t0:.0} rps -> {t1:.0} rps"
        );
    }
    // Every shard of the widest fleet actually served traffic.
    let widest = &rows.last().unwrap().3;
    assert!(
        widest.iter().all(|&c| c > 0),
        "routing left shards idle: {widest:?}"
    );
    println!(
        "\n1 -> 4 shards: {:.2}x aggregate throughput — scaling OK",
        rows[2].2 / rows[0].2
    );
    Ok(())
}
