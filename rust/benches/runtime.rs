//! Runtime execution benchmarks over the real artifacts:
//! * per-stage latency at each batch size (amortization of batching);
//! * composed per-stage pipeline vs the monolithic full-model artifact
//!   (the L2 fusion ablation: what stage-boundary materialization costs);
//! * Pallas-lowered ('pl') vs XLA-fused ('ref') artifact flavors.
//!
//!     cargo bench --bench runtime

mod common;

use std::time::Duration;

use branchyserve::config::settings::Flavor;
use branchyserve::harness::{bench, print_table, BenchResult};
use branchyserve::runtime::HostTensor;

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let engine = common::engine(Flavor::Ref, "bench-ref")?;
    let m = engine.manifest().clone();
    let n = m.num_stages();

    // --- per-stage at batch sizes
    let mut rows: Vec<BenchResult> = Vec::new();
    for &b in &m.batch_sizes {
        let mut shape = vec![b];
        shape.extend(&m.input_shape);
        let x = HostTensor::zeros(shape);
        rows.push(bench(
            &format!("stage1 conv1 b={b} (per sample)"),
            Duration::from_millis(200),
            || {
                let out = engine.run_stages(1, 1, &x).unwrap();
                std::hint::black_box(out.len());
            },
        ));
    }
    print_table("stage-1 latency per batch size (whole batch)", &rows);

    // --- composed pipeline vs monolith, batch = max
    let b = engine.max_batch();
    let mut shape = vec![b];
    shape.extend(&m.input_shape);
    let x = HostTensor::zeros(shape);
    let mut rows = Vec::new();
    rows.push(bench(
        &format!("composed stages 1..={n} b={b}"),
        Duration::from_millis(300),
        || {
            let out = engine.run_stages(1, n, &x).unwrap();
            std::hint::black_box(out.len());
        },
    ));
    rows.push(bench(
        &format!("monolithic full model  b={b}"),
        Duration::from_millis(300),
        || {
            let out = engine.run_full(&x).unwrap();
            std::hint::black_box(out.len());
        },
    ));
    print_table("fusion ablation: composed stages vs monolith", &rows);

    // --- branch head
    let mut bshape = vec![b];
    bshape.extend(&m.branch.in_shape);
    let acts = HostTensor::zeros(bshape);
    let mut rows = Vec::new();
    rows.push(bench(
        &format!("branch b1 (probs+entropy) b={b}"),
        Duration::from_millis(200),
        || {
            let out = engine.run_branch(&acts).unwrap();
            std::hint::black_box(out.entropy.len());
        },
    ));
    print_table("side-branch head", &rows);

    // --- flavor comparison (pl = Pallas-lowered interpret-mode HLO)
    let engine_pl = common::engine(Flavor::Pallas, "bench-pl")?;
    let mut rows = Vec::new();
    for (flavor, eng) in [("ref", &engine), ("pl", &engine_pl)] {
        let mut shape = vec![1];
        shape.extend(&m.input_shape);
        let x1 = HostTensor::zeros(shape);
        rows.push(bench(
            &format!("stage1 conv1 flavor={flavor} b=1"),
            Duration::from_millis(300),
            || {
                let out = eng.run_stages(1, 1, &x1).unwrap();
                std::hint::black_box(out.len());
            },
        ));
    }
    print_table("kernel flavor: XLA-fused ref vs Pallas-lowered pl", &rows);
    Ok(())
}
