//! Shared setup for the bench targets: artifacts dir discovery, manifest
//! + measured profile loading (profiling on the spot if no cache).

use std::path::PathBuf;

use branchyserve::config::settings::Flavor;
use branchyserve::model::Manifest;
use branchyserve::profiler::{self, ProfileOptions, ProfileReport};
use branchyserve::runtime::InferenceEngine;

pub fn artifacts_dir() -> PathBuf {
    std::env::var("BRANCHYSERVE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

pub fn manifest_and_profile() -> anyhow::Result<(Manifest, ProfileReport)> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let cached = dir.join("profile.json");
    let report = if cached.exists() {
        ProfileReport::load(&cached)?
    } else {
        let engine = InferenceEngine::open(&dir, manifest.clone(), Flavor::Ref, "bench")?;
        let r = profiler::measure(&engine, ProfileOptions::default())?;
        r.save(&cached).ok();
        r
    };
    Ok((manifest, report))
}

#[allow(dead_code)]
pub fn engine(flavor: Flavor, name: &str) -> anyhow::Result<InferenceEngine> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    InferenceEngine::open(&dir, manifest, flavor, name)
}
