//! Solver performance: G'_BDNN construction + Dijkstra vs the O(N^2)
//! brute-force baseline, across chain depth and branch density. The
//! paper's complexity argument (§V: polynomial shortest path vs
//! exhaustive search) made concrete.
//!
//!     cargo bench --bench solver

use std::time::Duration;

use branchyserve::harness::{bench, print_table, BenchResult};
use branchyserve::model::synthetic;
use branchyserve::network::bandwidth::LinkModel;
use branchyserve::partition::{brute, solver};
use branchyserve::timing::Estimator;

fn main() {
    branchyserve::util::logger::init();
    let link = LinkModel::new(5.85, 0.0);
    let mut rows: Vec<BenchResult> = Vec::new();

    for &n in &[8usize, 64, 256, 1024, 4096] {
        for &branch_every in &[0usize, 8] {
            let (desc, profile) = synthetic::deep_chain(n, branch_every, 0.3, 42);
            let label_suffix = if branch_every == 0 {
                "no branches".to_string()
            } else {
                format!("branch every {branch_every}")
            };

            rows.push(bench(
                &format!("compact graph n={n} ({label_suffix})"),
                Duration::from_millis(150),
                || {
                    let plan = solver::solve(&desc, &profile, link, 1e-9, true);
                    std::hint::black_box(plan.split_after);
                },
            ));
            rows.push(bench(
                &format!("faithful G'   n={n} ({label_suffix})"),
                Duration::from_millis(150),
                || {
                    let plan = solver::solve_faithful(&desc, &profile, link, 1e-9, true);
                    std::hint::black_box(plan.split_after);
                },
            ));
            rows.push(bench(
                &format!("brute-force   n={n} ({label_suffix})"),
                Duration::from_millis(150),
                || {
                    let est = Estimator::new(&desc, &profile, link).paper_mode();
                    let plan = brute::solve(&est);
                    std::hint::black_box(plan.split_after);
                },
            ));
        }
    }
    print_table("partition solver scaling", &rows);

    // Sanity: both agree on the B-AlexNet-sized instance.
    let (desc, profile) = synthetic::deep_chain(8, 4, 0.5, 7);
    let sp = solver::solve(&desc, &profile, link, 1e-9, true);
    let est = Estimator::new(&desc, &profile, link).paper_mode();
    let bf = brute::solve(&est);
    assert!(
        (sp.expected_time_s - bf.expected_time_s).abs() < 1e-9,
        "solver {} vs brute {}",
        sp.expected_time_s,
        bf.expected_time_s
    );
    println!("\nsolver == brute force on sanity instance: OK");
}
