//! Solver performance ablation across chain depth and branch density:
//! the planner's precomputed O(N) sweep vs the compact graph + Dijkstra
//! vs the paper-faithful `G'_BDNN` construction vs the O(N²) brute
//! force. The paper's complexity argument (§V: polynomial shortest path
//! vs exhaustive search) made concrete, plus the planner refactor's
//! claim that replanning needs no graph at all.
//!
//!     cargo bench --bench solver

use std::time::Duration;

use branchyserve::harness::{bench, print_table, BenchResult};
use branchyserve::model::synthetic;
use branchyserve::network::bandwidth::LinkModel;
use branchyserve::partition::{brute, compact, solver};
use branchyserve::planner::Planner;
use branchyserve::timing::Estimator;

fn main() {
    branchyserve::util::logger::init();
    let link = LinkModel::new(5.85, 0.0);
    let mut rows: Vec<BenchResult> = Vec::new();

    for &n in &[8usize, 64, 256, 1024, 4096] {
        for &branch_every in &[0usize, 8] {
            let (desc, profile) = synthetic::deep_chain(n, branch_every, 0.3, 42);
            let label_suffix = if branch_every == 0 {
                "no branches".to_string()
            } else {
                format!("branch every {branch_every}")
            };

            rows.push(bench(
                &format!("planner cold  n={n} ({label_suffix})"),
                Duration::from_millis(150),
                || {
                    let plan = solver::solve(&desc, &profile, link, 1e-9, true);
                    std::hint::black_box(plan.split_after);
                },
            ));
            let planner = Planner::new(&desc, &profile, 1e-9, true);
            rows.push(bench(
                &format!("planner warm  n={n} ({label_suffix})"),
                Duration::from_millis(150),
                || {
                    let plan = planner.plan_for(link);
                    std::hint::black_box(plan.split_after);
                },
            ));
            rows.push(bench(
                &format!("compact graph n={n} ({label_suffix})"),
                Duration::from_millis(150),
                || {
                    let (split, _) = compact::solve_split(&desc, &profile, link, 1e-9, false);
                    std::hint::black_box(split);
                },
            ));
            rows.push(bench(
                &format!("faithful G'   n={n} ({label_suffix})"),
                Duration::from_millis(150),
                || {
                    let plan = solver::solve_faithful(&desc, &profile, link, 1e-9, true);
                    std::hint::black_box(plan.split_after);
                },
            ));
            rows.push(bench(
                &format!("brute-force   n={n} ({label_suffix})"),
                Duration::from_millis(150),
                || {
                    let est = Estimator::new(&desc, &profile, link).paper_mode();
                    let plan = brute::solve(&est);
                    std::hint::black_box(plan.split_after);
                },
            ));
        }
    }
    print_table("partition solver scaling", &rows);

    // Sanity: both agree on the B-AlexNet-sized instance.
    let (desc, profile) = synthetic::deep_chain(8, 4, 0.5, 7);
    let sp = solver::solve(&desc, &profile, link, 1e-9, true);
    let est = Estimator::new(&desc, &profile, link).paper_mode();
    let bf = brute::solve(&est);
    assert!(
        (sp.expected_time_s - bf.expected_time_s).abs() < 1e-9,
        "solver {} vs brute {}",
        sp.expected_time_s,
        bf.expected_time_s
    );
    println!("\nsolver == brute force on sanity instance: OK");
}
