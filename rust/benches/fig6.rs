//! Regenerates paper Figure 6: probability of side-branch classification
//! vs entropy threshold under Gaussian blur {none, 5, 15, 65}, measured
//! on the trained B-AlexNet through the PJRT runtime with the paper's
//! 48-sample batches.
//!
//!     cargo bench --bench fig6

mod common;

use branchyserve::config::settings::Flavor;
use branchyserve::experiments::fig6;
use branchyserve::harness::Table;

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let engine = common::engine(Flavor::Ref, "fig6")?;
    let results = fig6::run(&engine)?;
    let max_nats = engine.manifest().entropy_max_nats;

    let headers: Vec<String> = std::iter::once("threshold".to_string())
        .chain(results.iter().map(|r| format!("{} (k={})", r.level, r.blur_ksize)))
        .collect();
    let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut table = Table::new(&headers_ref);
    let points = 15;
    for i in 0..points {
        let thr = i as f64 / (points - 1) as f64 * max_nats;
        let mut row = vec![format!("{thr:.3}")];
        for r in &results {
            row.push(format!("{:.3}", r.exit_probability(thr)));
        }
        table.row(row);
    }
    println!("### Fig. 6 — P[classified at side branch] vs entropy threshold");
    println!("{}", table.render());
    for r in &results {
        println!(
            "{:>5} (k={:>2}): mean entropy {:.4} nats, branch accuracy {:.3}",
            r.level,
            r.blur_ksize,
            r.entropies.iter().map(|&e| e as f64).sum::<f64>() / r.entropies.len() as f64,
            r.branch_accuracy
        );
    }

    // Shape checks — the paper's claim: "as distortion level increases,
    // the probability that a sample is classified at a side branch
    // decreases" (dominance of less-blurred curves), with curves rising
    // from 0 to 1 across the threshold range.
    for r in &results {
        assert!((r.exit_probability(0.0) - 0.0).abs() < 1e-12);
        assert!((r.exit_probability(max_nats + 1e-6) - 1.0).abs() < 1e-12);
    }
    let mean_ent: Vec<f64> = results
        .iter()
        .map(|r| r.entropies.iter().map(|&e| e as f64).sum::<f64>() / r.entropies.len() as f64)
        .collect();
    for w in mean_ent.windows(2) {
        assert!(
            w[1] > w[0] - 1e-9,
            "mean entropy must not decrease with blur: {mean_ent:?}"
        );
    }
    // Curve dominance at the operating region (mid thresholds).
    for thr in [0.2, 0.3, 0.4] {
        let ps: Vec<f64> = results.iter().map(|r| r.exit_probability(thr)).collect();
        assert!(
            ps[0] >= ps[1] && ps[1] >= ps[2] && ps[2] >= ps[3],
            "exit probability should fall with blur at thr={thr}: {ps:?}"
        );
    }
    println!("\nall Fig. 6 shape checks PASSED");
    Ok(())
}
