//! Wire-path bench: bytes-on-wire and p99 latency for the edge->cloud
//! activation transfer, across the codec (raw f32 / q8 / q4) and the
//! framing discipline (lockstep round-trips vs pipelined seq frames).
//!
//! Every cell replays the same fixed trace against a loopback
//! [`CloudStageServer`]: N batches of 4 samples, cut at split 1 of a
//! three-stage sim net (256 f32 per sample on the wire raw). Two
//! numbers come out per cell:
//!
//!   * `bytes/req` — measured framed bytes (client counters, which the
//!     loopback q8 integration test proves agree with the server's).
//!   * `p99 e2e @3G` — measured loopback p99 (compute + framing +
//!     pipeline queueing) plus the paper's 3G link model
//!     (`LinkModel::from_profile`, 1.10 Mbps) serializing that cell's
//!     measured per-request bytes. Loopback can't starve a real
//!     uplink, so the wire term is modeled from measured bytes; the
//!     concurrency term is measured for real.
//!
//! "Lockstep" pins `max_inflight = 1` — the pre-pipelining engine's
//! one-round-trip-at-a-time rhythm. "Pipelined" runs 8 closed-loop
//! workers over a single pooled connection (`pool_capacity = 1`) so
//! every in-flight frame shares one stream, which is exactly the case
//! sequence tags exist for.
//!
//! Writes the latest run to `BENCH_wire.json` (repo root) in the shape
//! `scripts/bench_record.py` merges and gates on. `SMOKE=1` shortens
//! the trace for CI; the acceptance asserts hold either way because
//! the byte ratio is deterministic and the 3G wire term dominates p99.
//!
//! Acceptance (hard asserts):
//!   * q8+pipelined ships >= 3.5x fewer bytes than raw+lockstep;
//!   * q8+pipelined p99 e2e @3G beats raw+lockstep.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use branchyserve::model::Manifest;
use branchyserve::network::{LinkModel, Profile, WireEncoding};
use branchyserve::runtime::{HostTensor, InferenceEngine};
use branchyserve::server::{
    CloudStageServer, RemoteCloudConfig, RemoteCloudEngine, Server, ServerHandle,
};
use branchyserve::util::stats::percentile;

/// Samples per INFER_PARTIAL batch.
const BATCH: usize = 4;
/// Elements per sample at the cut (stage 1's output width).
const ELEMS: usize = 256;
/// Split the trace ships at (stage 1 runs on the edge, 2..=3 remote).
const SPLIT: usize = 1;
/// Closed-loop workers in pipelined mode.
const WORKERS: usize = 8;

#[derive(Clone, Copy, PartialEq, Eq)]
enum Mode {
    Lockstep,
    Pipelined,
}

impl Mode {
    fn as_str(self) -> &'static str {
        match self {
            Mode::Lockstep => "lockstep",
            Mode::Pipelined => "pipelined",
        }
    }
}

struct Cell {
    encoding: WireEncoding,
    mode: Mode,
    requests: u64,
    bytes_sent: u64,
    bytes_received: u64,
    p99_loopback_us: f64,
    p99_e2e_3g_ms: f64,
    throughput_rps: f64,
    inflight_peak: u64,
}

impl Cell {
    fn bytes_sent_per_req(&self) -> f64 {
        self.bytes_sent as f64 / self.requests as f64
    }
}

/// Deterministic activation batch: same values every run and every cell,
/// spread across [-1, 1) so q8/q4 quantization has real dynamic range.
fn trace_batch() -> HostTensor {
    let n = BATCH * ELEMS;
    let data: Vec<f32> = (0..n)
        .map(|i| (i.wrapping_mul(2_654_435_761) % 2000) as f32 / 1000.0 - 1.0)
        .collect();
    HostTensor::new(vec![BATCH, ELEMS], data).expect("trace batch shape")
}

fn fresh_server(stage_cost: Duration) -> anyhow::Result<(ServerHandle, Arc<CloudStageServer>)> {
    let manifest = Manifest::synthetic_sim(
        "sim-wire",
        vec![64],
        &[ELEMS, 64, 2],
        1,
        2,
        vec![1, 2, 4, 8],
    )?;
    let css = Arc::new(CloudStageServer::new(InferenceEngine::open_sim_with_cost(
        manifest,
        "wire-srv",
        stage_cost,
    )?));
    let handle = Server::new(css.clone()).start(0)?;
    Ok((handle, css))
}

fn run_cell(
    encoding: WireEncoding,
    mode: Mode,
    requests: u64,
    stage_cost: Duration,
    link: LinkModel,
) -> anyhow::Result<Cell> {
    let (handle, _css) = fresh_server(stage_cost)?;
    let mut cfg = RemoteCloudConfig::new(handle.addr().to_string());
    cfg.encoding = encoding;
    cfg.pool_capacity = 1; // every frame shares one stream
    if mode == Mode::Lockstep {
        cfg.max_inflight = 1; // the old request->response->request rhythm
    }
    let eng = Arc::new(RemoteCloudEngine::new(cfg));
    let batch = trace_batch();

    // Warm the connection so neither mode pays the dial inside the
    // measured window (and pipelined workers share one stream instead
    // of racing to establish it).
    eng.infer_partial(SPLIT, 0, &batch)?;
    let base = eng.stats();

    let latencies: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    match mode {
        Mode::Lockstep => {
            let mut lat = Vec::with_capacity(requests as usize);
            for _ in 0..requests {
                let c0 = Instant::now();
                eng.infer_partial(SPLIT, 0, &batch)?;
                lat.push(c0.elapsed().as_secs_f64() * 1e6);
            }
            latencies.lock().unwrap().extend(lat);
        }
        Mode::Pipelined => {
            let per_worker = requests / WORKERS as u64;
            let mut joins = Vec::new();
            for _ in 0..WORKERS {
                let eng = eng.clone();
                let batch = batch.clone();
                let latencies = latencies.clone();
                joins.push(std::thread::spawn(move || -> anyhow::Result<()> {
                    let mut lat = Vec::with_capacity(per_worker as usize);
                    for _ in 0..per_worker {
                        let c0 = Instant::now();
                        eng.infer_partial(SPLIT, 0, &batch)?;
                        lat.push(c0.elapsed().as_secs_f64() * 1e6);
                    }
                    latencies.lock().unwrap().extend(lat);
                    Ok(())
                }));
            }
            for j in joins {
                j.join().expect("worker panicked")?;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = eng.stats();
    anyhow::ensure!(
        stats.failures == base.failures && stats.fast_fails == base.fast_fails,
        "loopback cell must not see failures ({encoding} encoding, {} mode)",
        mode.as_str()
    );

    let lat = latencies.lock().unwrap();
    let served = lat.len() as u64;
    let bytes_sent = stats.bytes_sent - base.bytes_sent;
    let bytes_received = stats.bytes_received - base.bytes_received;
    let p99_loopback_us = percentile(lat.as_slice(), 99.0);
    // One request's bytes serialized onto the paper's 3G uplink, on top
    // of the measured loopback p99. Both modes are charged the same
    // way, so the comparison isolates codec + framing.
    let wire_s = link.transfer_time((bytes_sent as f64 / served as f64).ceil() as u64);
    let p99_e2e_3g_ms = p99_loopback_us / 1e3 + wire_s * 1e3;

    let cell = Cell {
        encoding,
        mode,
        requests: served,
        bytes_sent,
        bytes_received,
        p99_loopback_us,
        p99_e2e_3g_ms,
        throughput_rps: served as f64 / wall,
        inflight_peak: stats.inflight_peak,
    };
    handle.stop();
    Ok(cell)
}

fn find(cells: &[Cell], e: WireEncoding, m: Mode) -> &Cell {
    cells
        .iter()
        .find(|c| c.encoding == e && c.mode == m)
        .expect("cell ran")
}

fn json_run(c: &Cell) -> String {
    format!(
        concat!(
            "    {{\"encoding\": \"{}\", \"mode\": \"{}\", \"requests\": {}, ",
            "\"bytes_sent\": {}, \"bytes_received\": {}, \"bytes_sent_per_request\": {:.1}, ",
            "\"p99_loopback_us\": {:.1}, \"p99_e2e_3g_ms\": {:.3}, ",
            "\"throughput_rps\": {:.1}, \"inflight_peak\": {}}}"
        ),
        c.encoding,
        c.mode.as_str(),
        c.requests,
        c.bytes_sent,
        c.bytes_received,
        c.bytes_sent_per_req(),
        c.p99_loopback_us,
        c.p99_e2e_3g_ms,
        c.throughput_rps,
        c.inflight_peak,
    )
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("SMOKE").is_ok();
    let requests: u64 = if smoke { 64 } else { 400 };
    let stage_cost = Duration::from_micros(if smoke { 60 } else { 120 });
    let link = LinkModel::from_profile(Profile::ThreeG);

    println!(
        "wire bench: {requests} reqs/cell, batch {BATCH} x {ELEMS} f32 at split {SPLIT}, \
         {WORKERS} workers pipelined, 3G = {:.2} Mbps{}",
        link.uplink_mbps,
        if smoke { " [smoke]" } else { "" }
    );
    println!(
        "{:<5} {:<10} {:>12} {:>14} {:>16} {:>12} {:>9}",
        "codec", "mode", "bytes/req", "p99 loop (us)", "p99 e2e @3G(ms)", "thru (r/s)", "inflight"
    );

    let mut cells: Vec<Cell> = Vec::new();
    for mode in [Mode::Lockstep, Mode::Pipelined] {
        for encoding in WireEncoding::ALL {
            let c = run_cell(encoding, mode, requests, stage_cost, link)?;
            println!(
                "{:<5} {:<10} {:>12.1} {:>14.1} {:>16.3} {:>12.1} {:>9}",
                c.encoding.as_str(),
                c.mode.as_str(),
                c.bytes_sent_per_req(),
                c.p99_loopback_us,
                c.p99_e2e_3g_ms,
                c.throughput_rps,
                c.inflight_peak,
            );
            cells.push(c);
        }
    }

    let raw_lockstep = find(&cells, WireEncoding::Raw, Mode::Lockstep);
    let q8_pipelined = find(&cells, WireEncoding::Q8, Mode::Pipelined);
    let bytes_cut = raw_lockstep.bytes_sent_per_req() / q8_pipelined.bytes_sent_per_req();
    let p99_cut = raw_lockstep.p99_e2e_3g_ms / q8_pipelined.p99_e2e_3g_ms;
    println!(
        "q8+pipelined vs raw+lockstep: {bytes_cut:.2}x fewer bytes, {p99_cut:.2}x lower p99 e2e @3G"
    );

    // Acceptance bars. The byte ratio is a codec identity (deterministic);
    // the p99 bar holds because the modeled 3G wire term dominates and the
    // pipelined loopback term is bounded by in-flight queueing.
    assert!(
        bytes_cut >= 3.5,
        "q8+pipelined must cut bytes >= 3.5x vs raw+lockstep, got {bytes_cut:.2}x"
    );
    assert!(
        q8_pipelined.p99_e2e_3g_ms < raw_lockstep.p99_e2e_3g_ms,
        "q8+pipelined p99 e2e @3G ({:.3} ms) must beat raw+lockstep ({:.3} ms)",
        q8_pipelined.p99_e2e_3g_ms,
        raw_lockstep.p99_e2e_3g_ms
    );
    assert!(
        q8_pipelined.inflight_peak > 1,
        "pipelined cell never had frames in flight concurrently"
    );

    let runs: Vec<String> = cells.iter().map(json_run).collect();
    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"wire\",\n",
            "  \"source\": \"measured\",\n",
            "  \"smoke\": {},\n",
            "  \"trace\": {{\"requests_per_cell\": {}, \"batch\": {}, \"elems_per_sample\": {}, ",
            "\"split\": {}, \"pipeline_workers\": {}, \"sim_stage_cost_us\": {}}},\n",
            "  \"link\": {{\"name\": \"3g\", \"uplink_mbps\": {:.2}, \"rtt_ms\": {:.1}}},\n",
            "  \"runs\": [\n{}\n  ],\n",
            "  \"derived\": {{\"bytes_cut_q8_pipelined_vs_raw_lockstep\": {:.2}, ",
            "\"p99_e2e_3g_cut_q8_pipelined_vs_raw_lockstep\": {:.2}}}\n",
            "}}\n"
        ),
        smoke,
        requests,
        BATCH,
        ELEMS,
        SPLIT,
        WORKERS,
        stage_cost.as_micros(),
        link.uplink_mbps,
        link.rtt_s * 1e3,
        runs.join(",\n"),
        bytes_cut,
        p99_cut,
    );
    std::fs::write("BENCH_wire.json", &json)?;
    println!("wrote BENCH_wire.json");
    Ok(())
}
