//! Regenerates paper Figure 4: expected inference time vs side-branch
//! exit probability, gamma in {10, 100, 1000} x {3G, 4G, WiFi}.
//!
//!     cargo bench --bench fig4
//!
//! Uses the measured per-stage profile (artifacts/profile.json if cached,
//! else measures on the spot) — the same substitution for the paper's
//! Colab K80 documented in DESIGN.md §4. Absolute times differ from the
//! paper; the assertions at the bottom check the paper's *shape* claims.

mod common;

use branchyserve::experiments::fig4;
use branchyserve::harness::Table;
use branchyserve::network::bandwidth::Profile;
use branchyserve::util::timefmt::format_secs;

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let (manifest, report) = common::manifest_and_profile()?;
    let desc = manifest.to_desc(0.0);
    let curves = fig4::run(&desc, &report.to_delay_profile(1.0), 21, 1e-9);

    for &gamma in &fig4::GAMMAS {
        println!("\n### Fig. 4 — gamma = {gamma}");
        let mut table = Table::new(&["p", "3G", "4G", "WiFi"]);
        let get = |net: Profile| {
            curves
                .iter()
                .find(|c| c.gamma == gamma && c.network == net)
                .unwrap()
        };
        let (c3, c4, cw) = (get(Profile::ThreeG), get(Profile::FourG), get(Profile::WiFi));
        for i in 0..c3.points.len() {
            table.row(vec![
                format!("{:.2}", c3.points[i].0),
                format_secs(c3.points[i].1),
                format_secs(c4.points[i].1),
                format_secs(cw.points[i].1),
            ]);
        }
        println!("{}", table.render());
        println!(
            "reduction p=0 -> p=1:  3G {:.2}%  4G {:.2}%  WiFi {:.2}%  \
             (paper @gamma=10: 87.27 / 82.98 / 70)",
            c3.reduction_pct(),
            c4.reduction_pct(),
            cw.reduction_pct()
        );
    }

    // Shape checks (the claims, not the absolute numbers):
    let at = |gamma: f64, net: Profile| {
        curves
            .iter()
            .find(|c| c.gamma == gamma && c.network == net)
            .unwrap()
    };
    // 1) lower bandwidth -> larger probability effect (gamma = 10).
    let (r3, r4, rw) = (
        at(10.0, Profile::ThreeG).reduction_pct(),
        at(10.0, Profile::FourG).reduction_pct(),
        at(10.0, Profile::WiFi).reduction_pct(),
    );
    assert!(r3 > r4 && r4 > rw, "ordering violated: {r3} {r4} {rw}");
    // 2) p = 1 equalizes technologies at gamma = 10 — the regime the
    //    paper demonstrates it in (Fig. 4a): with a strong edge, p = 1
    //    makes the optimum the all-edge prefix, which no longer depends
    //    on bandwidth. (At gamma >= 100 cloud-only can stay optimal for
    //    fast networks even at p = 1, so no equalization is expected —
    //    the paper's own Fig. 4b WiFi flat line.)
    {
        let last = |net: Profile| at(10.0, net).points.last().unwrap().1;
        let (a, b, c) = (
            last(Profile::ThreeG),
            last(Profile::FourG),
            last(Profile::WiFi),
        );
        assert!(
            (a - b).abs() < 1e-9 && (b - c).abs() < 1e-9,
            "gamma=10: p=1 should equalize, got {a} {b} {c}"
        );
    }
    // 3) weaker edges (larger gamma) show plateaus: at gamma = 1000 the
    //    low-p region must be flat (cloud-only regime) for WiFi.
    let cw = at(1000.0, Profile::WiFi);
    let flat = cw.points.windows(2).take(5).all(|w| (w[0].1 - w[1].1).abs() < 1e-12);
    assert!(flat, "gamma=1000 WiFi low-p region should be cloud-only flat");
    println!("\nall Fig. 4 shape checks PASSED");

    // ---- paper-scale calibration: the paper's B-AlexNet ingests 224x224
    // images (ours: 32x32), so its alpha/compute ratio is ~49x ours. With
    // alpha scaled to the paper's geometry the reduction percentages land
    // near the quoted 87.27 / 82.98 / 70.
    let paper_desc = desc.scale_alpha(49.0);
    let paper_curves = fig4::run(&paper_desc, &report.to_delay_profile(1.0), 21, 1e-9);
    let red = |net: Profile| {
        paper_curves
            .iter()
            .find(|c| c.gamma == 10.0 && c.network == net)
            .unwrap()
            .reduction_pct()
    };
    let (r3, r4, rw) = (red(Profile::ThreeG), red(Profile::FourG), red(Profile::WiFi));
    println!(
        "\npaper-scale (alpha x49, gamma=10) reduction p=0 -> p=1: \
         3G {r3:.2}%  4G {r4:.2}%  WiFi {rw:.2}%  (paper: 87.27 / 82.98 / 70)"
    );
    // At x49 the upload is so expensive that the optimizer already avoids
    // the network at p = 0 (edge-only), collapsing the three reductions
    // to the same large value — the ordering claim is strict only at
    // native scale (asserted above); here we check magnitude + weak order.
    assert!(r3 >= r4 - 1e-9 && r4 >= rw - 1e-9, "paper-scale weak ordering violated");
    assert!(r3 > 60.0, "paper-scale 3G reduction should be large, got {r3:.1}%");
    Ok(())
}
