//! Front-end bench: accepted connections and sustained req/s with ~1k
//! concurrent loopback connections, thread-per-connection vs the epoll
//! reactor, behind the same [`Server`] API.
//!
//! The backend is a canned-answer [`ServeBackend`] that classifies
//! every frame instantly, so both cells measure the *front end* —
//! accept, framing, dispatch, write-back — not model execution. Every
//! client thread holds K open connections and drives them in rounds:
//! write `DEPTH` INFER frames per connection in one segment, then read
//! the `DEPTH` answers back, for every connection, `rounds` times. All
//! connections stay open for the whole cell, so `conn_peak` proves the
//! concurrency level actually held.
//!
//! Connection count adapts to `RLIMIT_NOFILE` (client and server ends
//! live in one process, so each connection costs two fds); the clamp is
//! printed when it bites. `SMOKE=1` shrinks the fleet for CI.
//!
//! Writes `BENCH_serve.json` (repo root) in the shape
//! `scripts/bench_record.py` merges and gates on.
//!
//! Acceptance (hard asserts):
//!   * every cell serves its full request count, answers decode as
//!     RESULT, and `conn_peak` >= the concurrency target;
//!   * full run, Linux: reactor sustains >= 2x the thread-per-conn
//!     req/s.

use std::io::{BufReader, Write as _};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

use anyhow::Result;
use branchyserve::coordinator::request::ExitPoint;
use branchyserve::coordinator::InferenceResponse;
use branchyserve::runtime::HostTensor;
use branchyserve::server::protocol::{read_frame, write_frame};
use branchyserve::server::{
    Request, Response, ServeBackend, Server, ServerConfig, ServerHandle, ServerStatsSnapshot,
};
use branchyserve::util::stats::percentile;

/// Client threads; each owns `conns / CLIENT_THREADS` connections.
const CLIENT_THREADS: usize = 8;
/// INFER frames written per connection per round, in one segment.
const DEPTH: usize = 4;
/// Reactor threads for the reactor cell.
const REACTOR_THREADS: usize = 2;
/// fds reserved for everything that is not a benched connection
/// (listener, epoll, eventfds, stdio, slack).
const FD_SLACK: u64 = 96;

/// Canned-answer backend: the cheapest possible [`ServeBackend`], so
/// the bench isolates front-end cost. The entropy echoes the first
/// element of the decoded image, which keeps the decode honest.
struct EchoBackend {
    served: AtomicU64,
}

impl EchoBackend {
    fn new() -> Self {
        Self {
            served: AtomicU64::new(0),
        }
    }
}

impl ServeBackend for EchoBackend {
    fn serve_infer(&self, class: Option<u8>, image: HostTensor) -> Result<InferenceResponse> {
        let id = self.served.fetch_add(1, Ordering::Relaxed);
        Ok(InferenceResponse {
            id,
            class: class.unwrap_or(0) as usize,
            exit: ExitPoint::EdgeBranch,
            entropy: image.data().first().copied().unwrap_or(0.0),
            latency_s: 0.0,
            edge_s: 0.0,
            transfer_s: 0.0,
            cloud_s: 0.0,
        })
    }

    fn metrics_json(&self) -> String {
        format!("{{\"served\": {}}}", self.served.load(Ordering::Relaxed))
    }
}

struct Cell {
    mode: &'static str,
    conns: usize,
    requests: u64,
    wall_s: f64,
    req_per_s: f64,
    p99_round_ms: f64,
    stats: ServerStatsSnapshot,
}

/// Soft RLIMIT_NOFILE via /proc (Linux); `None` elsewhere — the
/// portable cell sizes then trust the requested count.
fn soft_fd_limit() -> Option<u64> {
    let limits = std::fs::read_to_string("/proc/self/limits").ok()?;
    let line = limits.lines().find(|l| l.starts_with("Max open files"))?;
    line.split_whitespace().nth(3)?.parse().ok()
}

/// One INFER frame (header + body) as raw bytes, tiny on purpose: the
/// bench stresses connection count, not payload size.
fn framed_request() -> Result<Vec<u8>> {
    let image = HostTensor::new(vec![4], vec![0.25, -0.5, 0.75, -1.0])?;
    let body = Request::Infer(image).encode();
    let mut buf = Vec::new();
    write_frame(&mut buf, &body)?;
    Ok(buf)
}

fn run_cell(mode: &'static str, cfg: ServerConfig, conns: usize, rounds: usize) -> Result<Cell> {
    let handle: ServerHandle = Server::with_config(Arc::new(EchoBackend::new()), cfg).start(0)?;
    let addr = handle.addr();
    let frame = framed_request()?;

    let per_thread = conns / CLIENT_THREADS;
    let barrier = Arc::new(Barrier::new(CLIENT_THREADS + 1));
    let round_times: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));

    let mut joins = Vec::new();
    for _ in 0..CLIENT_THREADS {
        let frame = frame.clone();
        let barrier = barrier.clone();
        let round_times = round_times.clone();
        joins.push(std::thread::spawn(move || -> Result<u64> {
            // One burst segment per connection per round: DEPTH frames
            // back to back, which a multiplexing front end must parse
            // out of a single readable event.
            let burst = frame.repeat(DEPTH);
            let mut streams = Vec::with_capacity(per_thread);
            for _ in 0..per_thread {
                let s = TcpStream::connect(addr)?;
                s.set_nodelay(true)?;
                streams.push(BufReader::new(s));
            }
            // Every connection is open before any cell traffic starts.
            barrier.wait();
            let mut served = 0u64;
            let mut laps = Vec::with_capacity(rounds);
            for _ in 0..rounds {
                let r0 = Instant::now();
                for s in &mut streams {
                    s.get_mut().write_all(&burst)?;
                }
                for s in &mut streams {
                    for _ in 0..DEPTH {
                        let body = read_frame(s)?;
                        match Response::decode(&body)? {
                            Response::Result { .. } => served += 1,
                            other => anyhow::bail!("expected RESULT, got {other:?}"),
                        }
                    }
                }
                laps.push(r0.elapsed().as_secs_f64() * 1e3);
            }
            round_times.lock().unwrap().extend(laps);
            Ok(served)
        }));
    }

    barrier.wait(); // all conns connected — the timed window is pure traffic
    let t0 = Instant::now();
    let mut requests = 0u64;
    for j in joins {
        requests += j.join().expect("client thread panicked")?;
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let stats = handle.stats().snapshot();
    handle.stop();

    let expected = (per_thread * CLIENT_THREADS * rounds * DEPTH) as u64;
    assert_eq!(
        requests, expected,
        "{mode}: every request must come back as RESULT"
    );
    assert!(
        stats.conn_peak >= (per_thread * CLIENT_THREADS) as u64,
        "{mode}: conn_peak {} never reached the concurrency target {}",
        stats.conn_peak,
        per_thread * CLIENT_THREADS
    );

    let laps = round_times.lock().unwrap();
    Ok(Cell {
        mode,
        conns: per_thread * CLIENT_THREADS,
        requests,
        wall_s,
        req_per_s: requests as f64 / wall_s,
        p99_round_ms: percentile(laps.as_slice(), 99.0),
        stats,
    })
}

fn json_run(c: &Cell) -> String {
    format!(
        concat!(
            "    {{\"mode\": \"{}\", \"conns\": {}, \"requests\": {}, ",
            "\"wall_s\": {:.3}, \"req_per_s\": {:.1}, \"p99_round_ms\": {:.3}, ",
            "\"accepted\": {}, \"conn_peak\": {}, \"throttled\": {}, \"conns_shed\": {}}}"
        ),
        c.mode,
        c.conns,
        c.requests,
        c.wall_s,
        c.req_per_s,
        c.p99_round_ms,
        c.stats.accepted,
        c.stats.conn_peak,
        c.stats.throttled,
        c.stats.conns_shed,
    )
}

fn main() -> Result<()> {
    let smoke = std::env::var("SMOKE").is_ok();
    let target_conns: usize = if smoke { 128 } else { 1000 };
    let rounds: usize = if smoke { 10 } else { 40 };

    // Both ends of every connection live in this process: two fds each.
    let mut conns = target_conns;
    if let Some(limit) = soft_fd_limit() {
        let budget = (limit.saturating_sub(FD_SLACK) / 2) as usize;
        if budget < conns {
            println!("fd limit {limit}: clamping {conns} -> {budget} connections");
            conns = budget;
        }
    }
    conns = (conns / CLIENT_THREADS).max(1) * CLIENT_THREADS;

    println!(
        "serve bench: {conns} conns x {rounds} rounds x depth {DEPTH}, \
         {CLIENT_THREADS} client threads{}",
        if smoke { " [smoke]" } else { "" }
    );
    println!(
        "{:<16} {:>7} {:>10} {:>9} {:>12} {:>14} {:>10}",
        "mode", "conns", "requests", "wall (s)", "req/s", "p99 round(ms)", "conn_peak"
    );

    let mut cells: Vec<Cell> = Vec::new();
    let mut plan: Vec<(&'static str, ServerConfig)> = vec![("threads", ServerConfig::default())];
    if cfg!(target_os = "linux") {
        plan.push((
            "reactor",
            ServerConfig {
                reactor: true,
                reactor_threads: REACTOR_THREADS,
                ..ServerConfig::default()
            },
        ));
    } else {
        println!("reactor cell skipped: epoll front end is Linux-only");
    }
    for (mode, cfg) in plan {
        let c = run_cell(mode, cfg, conns, rounds)?;
        println!(
            "{:<16} {:>7} {:>10} {:>9.3} {:>12.1} {:>14.3} {:>10}",
            c.mode, c.conns, c.requests, c.wall_s, c.req_per_s, c.p99_round_ms, c.stats.conn_peak
        );
        cells.push(c);
    }

    let speedup = match (
        cells.iter().find(|c| c.mode == "threads"),
        cells.iter().find(|c| c.mode == "reactor"),
    ) {
        (Some(t), Some(r)) => {
            let s = r.req_per_s / t.req_per_s;
            println!("reactor vs thread-per-conn: {s:.2}x req/s");
            // The 2x bar is the full-scale claim: at smoke scale (128
            // conns) thread-per-conn has not hit its context-switch
            // wall yet, so only sanity-check that the reactor keeps up.
            if smoke {
                assert!(
                    s >= 0.5,
                    "reactor fell below half of thread-per-conn even at smoke scale ({s:.2}x)"
                );
            } else {
                assert!(
                    s >= 2.0,
                    "reactor must sustain >= 2x thread-per-conn req/s at {} conns, got {s:.2}x",
                    t.conns
                );
            }
            Some(s)
        }
        _ => None,
    };

    let runs: Vec<String> = cells.iter().map(json_run).collect();
    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"serve\",\n",
            "  \"source\": \"measured\",\n",
            "  \"smoke\": {},\n",
            "  \"config\": {{\"conns\": {}, \"rounds\": {}, \"depth\": {}, ",
            "\"client_threads\": {}, \"reactor_threads\": {}}},\n",
            "  \"runs\": [\n{}\n  ],\n",
            "  \"derived\": {{\"reactor_speedup\": {}}}\n",
            "}}\n"
        ),
        smoke,
        conns,
        rounds,
        DEPTH,
        CLIENT_THREADS,
        REACTOR_THREADS,
        runs.join(",\n"),
        speedup.map_or("null".to_string(), |s| format!("{s:.2}")),
    );
    std::fs::write("BENCH_serve.json", &json)?;
    println!("wrote BENCH_serve.json");
    Ok(())
}
