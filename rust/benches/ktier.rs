//! K-tier chain figure: the best two-tier plan (device → edge server
//! only) vs [`Planner::plan_chain`] over a device → edge server → cloud
//! chain, across the paper's uplink grid. Records to BENCH_ktier.json
//! for the CI gate (`scripts/bench_record.py`, kind "ktier").
//!
//!     cargo bench --bench ktier          # full grid
//!     SMOKE=1 cargo bench --bench ktier  # CI smoke: fewer cells
//!
//! The scenario: the device's only neighbour is a modest edge server
//! (4x slower than the datacentre) behind the constrained wireless
//! uplink; the edge server has a fast wired hop to the terminal cloud.
//! The two-tier baseline may only offload to the edge server; the
//! three-tier plan may continue onward.
//!
//! Acceptance bars (hard asserts): the three-tier plan never loses to
//! the best two-tier plan in any cell — every two-tier candidate `s`
//! embeds in the chain's space as `cuts = [s, N]` at identical cost, so
//! a loss is a DP bug, not a modelling choice — and at least one cell
//! is strictly better (continuing to the fast terminal must pay off
//! somewhere on the grid). All numbers are analytic (model evaluation,
//! no wall clock), so the recorded figures are deterministic across
//! machines.

use branchyserve::harness::Table;
use branchyserve::model::{BranchDesc, BranchyNetDesc};
use branchyserve::network::LinkModel;
use branchyserve::planner::{Planner, TierChain};
use branchyserve::timing::DelayProfile;
use branchyserve::util::timefmt::format_secs;

/// Edge-server compute penalty vs the terminal cloud.
const MIDDLE_SCALE: f64 = 4.0;
/// The edge server's wired hop to the terminal cloud.
const WIRED_MBPS: f64 = 1000.0;
const WIRED_RTT_S: f64 = 0.002;
/// The device's wireless RTT to the edge server.
const WIRELESS_RTT_S: f64 = 0.01;

/// The repo's B-AlexNet-shaped reference net (same fixture as fig_joint
/// and the ablation): non-monotonic activation sizes, one early exit
/// after stage 1 taking 20% of traffic, device 100x slower than the
/// terminal cloud.
fn fixture() -> (BranchyNetDesc, DelayProfile) {
    let desc = BranchyNetDesc {
        stage_names: (1..=8).map(|i| format!("s{i}")).collect(),
        stage_out_bytes: vec![57_600, 18_816, 25_088, 25_088, 3_456, 1_024, 512, 8],
        input_bytes: 12_288,
        branches: vec![BranchDesc {
            after_stage: 1,
            exit_prob: 0.2,
        }],
    };
    let profile = DelayProfile::from_cloud_times(
        vec![1e-3, 1.5e-3, 1.2e-3, 1.2e-3, 8e-4, 3e-4, 1e-4, 5e-5],
        2e-4,
        100.0,
    );
    (desc, profile)
}

struct Cell {
    mbps: f64,
    two_cut: usize,
    two_time: f64,
    three_cuts: Vec<usize>,
    three_time: f64,
}

impl Cell {
    fn improvement_pct(&self) -> f64 {
        (1.0 - self.three_time / self.two_time) * 100.0
    }
    fn strictly_better(&self) -> bool {
        self.three_time < self.two_time
    }
}

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let smoke = std::env::var("SMOKE").is_ok();
    let (desc, profile) = fixture();
    let planner = Planner::new(&desc, &profile, 1e-9, false);
    let bandwidths: Vec<f64> = if smoke {
        vec![1.10, 18.80]
    } else {
        vec![0.05, 0.35, 1.10, 5.85, 18.80, 100.0]
    };

    let wired = LinkModel::new(WIRED_MBPS, WIRED_RTT_S);
    let cells: Vec<Cell> = bandwidths
        .iter()
        .map(|&mbps| {
            let wireless = LinkModel::new(mbps, WIRELESS_RTT_S);
            let two_chain = TierChain {
                links: vec![wireless],
                compute_scale: vec![MIDDLE_SCALE],
            };
            let three_chain = TierChain {
                links: vec![wireless, wired],
                compute_scale: vec![MIDDLE_SCALE, 1.0],
            };
            let two = planner.plan_chain(&two_chain);
            let three = planner.plan_chain(&three_chain);
            Cell {
                mbps,
                two_cut: two.cuts[0],
                two_time: two.expected_time_s,
                three_cuts: three.cuts.clone(),
                three_time: three.expected_time_s,
            }
        })
        .collect();

    let mut table = Table::new(&[
        "Mbps", "2-tier s", "2-tier E[T]", "3-tier cuts", "3-tier E[T]", "gain %",
    ]);
    for c in &cells {
        table.row(vec![
            format!("{:.2}", c.mbps),
            c.two_cut.to_string(),
            format_secs(c.two_time),
            format!("{:?}", c.three_cuts),
            format_secs(c.three_time),
            format!("{:.2}", c.improvement_pct()),
        ]);
    }
    println!("### Three-tier chain vs best two-tier offload (edge server only)");
    println!("{}", table.render());

    let never_loses = cells.iter().all(|c| c.three_time <= c.two_time);
    let wins = cells.iter().filter(|c| c.strictly_better()).count();
    let max_gain = cells
        .iter()
        .map(|c| c.improvement_pct())
        .fold(0.0, f64::max);
    println!(
        "cells: {}  strict wins: {wins}  max gain: {max_gain:.2}%",
        cells.len()
    );

    // Acceptance bars — the two-tier space embeds in the chain's
    // (`cuts = [s, N]` prices identically), so a failure is a DP bug.
    assert!(never_loses, "three-tier plan lost to the two-tier plan somewhere");
    assert!(
        wins >= 1,
        "the chain found no strict win anywhere on the grid"
    );

    let cell_rows: Vec<String> = cells
        .iter()
        .map(|c| {
            format!(
                concat!(
                    "    {{\"mbps\": {}, \"two_cut\": {}, \"two_ms\": {:.6}, ",
                    "\"three_cuts\": [{}], \"three_ms\": {:.6}, ",
                    "\"improvement_pct\": {:.3}}}"
                ),
                c.mbps,
                c.two_cut,
                c.two_time * 1e3,
                c.three_cuts
                    .iter()
                    .map(|s| s.to_string())
                    .collect::<Vec<_>>()
                    .join(", "),
                c.three_time * 1e3,
                c.improvement_pct(),
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"ktier\",\n",
            "  \"source\": \"measured\",\n",
            "  \"smoke\": {},\n",
            "  \"cells\": [\n{}\n  ],\n",
            "  \"derived\": {{\n",
            "    \"three_tier_never_loses\": {},\n",
            "    \"cells_strictly_better\": {},\n",
            "    \"max_improvement_pct\": {:.3}\n",
            "  }}\n",
            "}}\n"
        ),
        smoke,
        cell_rows.join(",\n"),
        never_loses,
        wins,
        max_gain
    );
    std::fs::write("BENCH_ktier.json", &json)?;
    println!("wrote BENCH_ktier.json ({} cells)", cells.len());
    Ok(())
}
