//! Joint configuration search figure: the fixed-architecture optimum
//! vs `Planner::plan_joint` (branch placement × partition × precision)
//! across a bandwidth × exit-probability grid, at equal-or-better
//! accuracy proxy. Records to BENCH_joint.json for the CI gate
//! (`scripts/bench_record.py`, kind "joint").
//!
//!     cargo bench --bench fig_joint          # full grid
//!     SMOKE=1 cargo bench --bench fig_joint  # CI smoke: fewer cells
//!
//! Acceptance bars (hard asserts): the joint plan never loses to the
//! fixed plan in any cell, and at least one cell is strictly better.
//! The grid is analytic (model evaluation, no wall clock), so the
//! recorded numbers are deterministic across machines.

use branchyserve::experiments::fig_joint;
use branchyserve::harness::Table;
use branchyserve::model::{BranchDesc, BranchyNetDesc};
use branchyserve::timing::DelayProfile;
use branchyserve::util::timefmt::format_secs;

/// The repo's B-AlexNet-shaped reference net (same fixture as the
/// ablation and fig4 shape tests): non-monotonic activation sizes, one
/// early exit after stage 1, edge 10x slower than cloud.
fn fixture() -> (BranchyNetDesc, DelayProfile) {
    let desc = BranchyNetDesc {
        stage_names: (1..=8).map(|i| format!("s{i}")).collect(),
        stage_out_bytes: vec![57_600, 18_816, 25_088, 25_088, 3_456, 1_024, 512, 8],
        input_bytes: 12_288,
        branches: vec![BranchDesc {
            after_stage: 1,
            exit_prob: 0.0,
        }],
    };
    let profile = DelayProfile::from_cloud_times(
        vec![1e-3, 1.5e-3, 1.2e-3, 1.2e-3, 8e-4, 3e-4, 1e-4, 5e-5],
        2e-4,
        10.0,
    );
    (desc, profile)
}

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let smoke = std::env::var("SMOKE").is_ok();
    let (desc, profile) = fixture();
    let (bandwidths, probs) = if smoke {
        (vec![1.10, 18.80], vec![0.0, 0.6])
    } else {
        (
            fig_joint::DEFAULT_BANDWIDTHS_MBPS.to_vec(),
            fig_joint::DEFAULT_PROBS.to_vec(),
        )
    };
    let cells = fig_joint::run(&desc, &profile, &bandwidths, &probs, 1e-9);

    let mut table = Table::new(&[
        "Mbps", "p", "fixed s", "fixed E[T]", "joint s", "enc", "branches", "joint E[T]", "gain %",
    ]);
    for c in &cells {
        table.row(vec![
            format!("{:.2}", c.mbps),
            format!("{:.1}", c.p),
            c.fixed_split.to_string(),
            format_secs(c.fixed_time),
            c.joint_split.to_string(),
            c.joint_encoding.as_str().to_string(),
            format!("{:?}", c.joint_branches),
            format_secs(c.joint_time),
            format!("{:.2}", c.improvement_pct()),
        ]);
    }
    println!("### Joint search vs fixed architecture (accuracy floor = fixed proxy)");
    println!("{}", table.render());

    let never_loses = cells.iter().all(|c| c.joint_time <= c.fixed_time);
    let wins = cells.iter().filter(|c| c.strictly_better()).count();
    let max_gain = cells
        .iter()
        .map(|c| c.improvement_pct())
        .fold(0.0, f64::max);
    println!(
        "cells: {}  strict wins: {wins}  max gain: {max_gain:.2}%",
        cells.len()
    );

    // Acceptance bars — these hold by construction (the fixed
    // configuration is a candidate), so a failure is a search bug.
    assert!(never_loses, "joint plan lost to the fixed plan somewhere");
    assert!(
        wins >= 1,
        "joint search found no strict win anywhere on the grid"
    );

    let cell_rows: Vec<String> = cells
        .iter()
        .map(|c| {
            format!(
                concat!(
                    "    {{\"mbps\": {}, \"p\": {}, \"fixed_split\": {}, ",
                    "\"fixed_ms\": {:.6}, \"joint_split\": {}, \"encoding\": \"{}\", ",
                    "\"branches\": [{}], \"joint_ms\": {:.6}, \"improvement_pct\": {:.3}}}"
                ),
                c.mbps,
                c.p,
                c.fixed_split,
                c.fixed_time * 1e3,
                c.joint_split,
                c.joint_encoding.as_str(),
                c.joint_branches
                    .iter()
                    .map(|p| p.to_string())
                    .collect::<Vec<_>>()
                    .join(", "),
                c.joint_time * 1e3,
                c.improvement_pct(),
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"joint\",\n",
            "  \"source\": \"measured\",\n",
            "  \"smoke\": {},\n",
            "  \"cells\": [\n{}\n  ],\n",
            "  \"derived\": {{\n",
            "    \"joint_never_loses\": {},\n",
            "    \"cells_strictly_better\": {},\n",
            "    \"max_improvement_pct\": {:.3}\n",
            "  }}\n",
            "}}\n"
        ),
        smoke,
        cell_rows.join(",\n"),
        never_loses,
        wins,
        max_gain
    );
    std::fs::write("BENCH_joint.json", &json)?;
    println!("wrote BENCH_joint.json ({} cells)", cells.len());
    Ok(())
}
