//! Coordinator/serving-path benchmarks on the real artifacts:
//! * closed-loop single-request latency per strategy (edge-only /
//!   cloud-only / optimal split) — the serving twin of Fig. 4's model;
//! * open-loop throughput + tail latency at increasing offered load;
//! * batcher + protocol microbenchmarks (pure L3 overhead, no XLA).
//!
//!     cargo bench --bench coordinator

mod common;

use std::sync::Arc;
use std::time::Duration;

use branchyserve::config::settings::{Flavor, Strategy};
use branchyserve::coordinator::{Coordinator, CoordinatorConfig};
use branchyserve::harness::{bench, print_table, BenchResult, Table};
use branchyserve::network::bandwidth::{LinkModel, Profile};
use branchyserve::network::Channel;
use branchyserve::partition::{self, PartitionPlan};
use branchyserve::planner::Planner;
use branchyserve::server::protocol::{Request, Response};
use branchyserve::util::timefmt::{format_rate, format_secs};
use branchyserve::workload::{ImageSource, LoadGen};

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let (manifest, report) = common::manifest_and_profile()?;
    let gamma = 5.0;
    let link = LinkModel::from_profile(Profile::ThreeG);
    let profile = report.to_delay_profile(gamma);
    let desc = manifest.to_desc(0.6);

    // --- closed-loop latency per strategy
    let mut rows: Vec<BenchResult> = Vec::new();
    for strategy in [Strategy::ShortestPath, Strategy::EdgeOnly, Strategy::CloudOnly] {
        let plan: PartitionPlan =
            partition::plan_with_strategy(strategy, &desc, &profile, link, 1e-9, false);
        let label = format!(
            "infer_sync {} (split '{}')",
            strategy.as_str(),
            plan.split_label(&desc)
        );
        let edge = common::engine(Flavor::Ref, "bench-edge")?;
        let cloud = common::engine(Flavor::Ref, "bench-cloud")?;
        edge.warmup()?;
        cloud.warmup()?;
        let coordinator = Coordinator::start(
            edge,
            cloud,
            Arc::new(Channel::from_link(link)),
            plan,
            CoordinatorConfig {
                entropy_threshold: 0.4,
                batch_timeout: Duration::from_micros(200),
                ..Default::default()
            },
        );
        let mut source = ImageSource::new(5);
        rows.push(bench(&label, Duration::from_millis(1500), || {
            let (img, _) = source.sample();
            let resp = coordinator.infer_sync(img).unwrap();
            std::hint::black_box(resp.class);
        }));
        coordinator.shutdown();
    }
    print_table("closed-loop single-request latency (gamma=5, 3G)", &rows);

    // --- open-loop load sweep on the optimal plan (planned through the
    // planner subsystem, the serving-path default)
    let plan = Planner::new(&desc, &profile, 1e-9, false).plan_for(link);
    let mut table = Table::new(&[
        "offered rps", "completed", "rejected", "throughput", "exit %", "mean", "p95", "p99",
    ]);
    for &rate in &[20.0, 60.0, 120.0] {
        let edge = common::engine(Flavor::Ref, "load-edge")?;
        let cloud = common::engine(Flavor::Ref, "load-cloud")?;
        edge.warmup()?;
        cloud.warmup()?;
        let coordinator = Coordinator::start(
            edge,
            cloud,
            Arc::new(Channel::from_link(link)),
            plan.clone(),
            CoordinatorConfig {
                entropy_threshold: 0.4,
                queue_capacity: 256,
                ..Default::default()
            },
        );
        let gen = LoadGen {
            rate_rps: rate,
            duration: Duration::from_secs(4),
            seed: 9,
        };
        let r = gen.run(&coordinator);
        table.row(vec![
            format!("{rate:.0}"),
            r.completed.to_string(),
            r.rejected.to_string(),
            format_rate(r.throughput()),
            format!("{:.1}", r.exit_rate() * 100.0),
            format_secs(r.mean_latency()),
            format_secs(r.p(95.0)),
            format_secs(r.p(99.0)),
        ]);
        coordinator.shutdown();
    }
    println!("\n=== open-loop load sweep (optimal plan) ===");
    println!("{}", table.render());

    // --- pure-L3 microbenches
    let mut rows = Vec::new();
    let mut source = ImageSource::new(1);
    let (img, _) = source.sample();
    rows.push(bench("protocol encode+decode INFER", Duration::from_millis(200), || {
        let req = Request::Infer(img.clone());
        let decoded = Request::decode(&req.encode()).unwrap();
        std::hint::black_box(matches!(decoded, Request::Infer(_)));
    }));
    let resp = Response::Result {
        id: 1,
        class: 1,
        exited_early: true,
        entropy: 0.2,
        latency_s: 0.01,
    };
    rows.push(bench("protocol encode+decode RESULT", Duration::from_millis(200), || {
        let decoded = Response::decode(&resp.encode()).unwrap();
        std::hint::black_box(matches!(decoded, Response::Result { .. }));
    }));
    rows.push(bench("image generation (workload)", Duration::from_millis(200), || {
        let (img, _) = source.sample();
        std::hint::black_box(img.len());
    }));
    print_table("L3 microbenchmarks", &rows);
    Ok(())
}
