//! p-view rebuild throughput: the cost of re-pointing a planner at new
//! exit probabilities. This is the operation online exit-rate feedback
//! performs on every drift trigger and the fleet performs once per link
//! class at startup, so it must be *much* cheaper than the full
//! `Planner::new` path it replaces (desc clone + re-validation + the
//! p-independent precompute) — the acceptance bar is `with_exit_probs`
//! ≥ 10× faster than cold construction at production-ish depth.
//!
//!     cargo bench --bench planner_p

use std::time::Duration;

use branchyserve::harness::{bench, print_table, BenchResult};
use branchyserve::model::synthetic;
use branchyserve::network::bandwidth::LinkModel;
use branchyserve::planner::Planner;
use branchyserve::util::timefmt::format_rate;

fn main() {
    branchyserve::util::logger::init();
    // SMOKE=1 (CI): shorter timing windows, same assertions.
    let window = if std::env::var("SMOKE").is_ok() {
        Duration::from_millis(60)
    } else {
        Duration::from_millis(200)
    };

    // Rotate through a spread of exit probabilities so every rebuild
    // derives a genuinely different view.
    let probs_grid: Vec<f64> = (0..64).map(|i| i as f64 / 63.0).collect();

    let mut rows: Vec<BenchResult> = Vec::new();
    let mut ratios: Vec<(usize, f64)> = Vec::new();

    for &n in &[64usize, 256, 1024, 4096] {
        // A few branches (every n/4 stages), like real BranchyNets — the
        // O(N·m) survival folds are shared by both paths; what differs
        // is everything with_exit_probs *skips*.
        let (desc, profile) = synthetic::deep_chain(n, n / 4, 0.3, 42);
        let m = desc.branches.len();

        let mut ic = probs_grid.iter().cycle();
        let cold = bench(&format!("cold Planner::new     n={n}"), window, || {
            let p = *ic.next().unwrap();
            let mut d = desc.clone();
            for b in &mut d.branches {
                b.exit_prob = p;
            }
            let planner = Planner::new(&d, &profile, 1e-9, false);
            std::hint::black_box(planner.num_stages());
        });

        // The view path: same StaticCore, one O(N·m) derive per call.
        let base = Planner::new(&desc, &profile, 1e-9, false);
        let mut iv = probs_grid.iter().cycle();
        let rebuild = bench(&format!("with_exit_probs       n={n}"), window, || {
            let p = *iv.next().unwrap();
            let view = base.with_exit_probs(&vec![p; m]);
            std::hint::black_box(view.num_stages());
        });

        // Sanity: the cheap path must agree with the cold one bit for
        // bit (the property test proves this exhaustively; this guards
        // the bench itself against drift).
        {
            let p = 0.37;
            let mut d = desc.clone();
            for b in &mut d.branches {
                b.exit_prob = p;
            }
            let fresh = Planner::new(&d, &profile, 1e-9, false);
            let cheap = base.with_exit_probs(&vec![p; m]);
            let link = LinkModel::new(5.85, 0.01);
            for s in 0..=n {
                assert_eq!(
                    cheap.expected_time(s, link).to_bits(),
                    fresh.expected_time(s, link).to_bits(),
                    "view drift at split {s}, n={n}"
                );
            }
        }

        ratios.push((n, cold.mean_s / rebuild.mean_s));
        rows.push(cold);
        rows.push(rebuild);
    }
    print_table("p-view rebuild vs cold planner construction", &rows);

    println!("\n=== rebuilds/sec ===");
    for (row, &(n, ratio)) in rows.chunks(2).zip(&ratios) {
        println!(
            "n={n:<5} cold {:>12}  with_exit_probs {:>12} ({ratio:6.1}x)",
            format_rate(1.0 / row[0].mean_s),
            format_rate(1.0 / row[1].mean_s),
        );
    }

    // Acceptance bar: at production-ish depth the view rebuild must beat
    // cold construction by >= 10x — otherwise the two-layer split isn't
    // paying for itself and the exit-feedback loop is too expensive to
    // run inline.
    let &(n, ratio) = ratios
        .iter()
        .find(|&&(n, _)| n == 1024)
        .expect("n=1024 measured");
    assert!(
        ratio >= 10.0,
        "with_exit_probs only {ratio:.1}x faster than cold Planner::new at n={n}"
    );
    println!("\nwith_exit_probs >= 10x cold construction at n=1024: OK ({ratio:.1}x)");
}
