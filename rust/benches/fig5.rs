//! Regenerates paper Figure 5: the partition layer chosen by the
//! optimizer vs the processing factor gamma, for 3G and 4G, one curve per
//! exit probability in {0.2, 0.5, 0.8, 1.0}.
//!
//!     cargo bench --bench fig5

mod common;

use branchyserve::experiments::fig5;
use branchyserve::harness::Table;
use branchyserve::network::bandwidth::Profile;

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let (manifest, report) = common::manifest_and_profile()?;
    let desc = manifest.to_desc(0.0);
    let gammas = fig5::gamma_grid(25, 2000.0);
    let curves = fig5::run(&desc, &report.to_delay_profile(1.0), &gammas, 1e-9);

    for net in [Profile::ThreeG, Profile::FourG] {
        println!("\n### Fig. 5 — {} (chosen partition layer per gamma)", net.name());
        let headers: Vec<String> = std::iter::once("gamma".to_string())
            .chain(fig5::PROBABILITIES.iter().map(|p| format!("p={p}")))
            .collect();
        let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut table = Table::new(&headers_ref);
        for (i, &gamma) in gammas.iter().enumerate() {
            let mut row = vec![format!("{gamma:.0}")];
            for &p in &fig5::PROBABILITIES {
                let c = curves
                    .iter()
                    .find(|c| c.network == net && c.probability == p)
                    .unwrap();
                row.push(c.points[i].2.clone());
            }
            table.row(row);
        }
        println!("{}", table.render());
    }

    // Shape checks:
    // 1) the split never moves deeper as gamma grows (per curve).
    for c in &curves {
        let splits: Vec<usize> = c.points.iter().map(|&(_, s, _)| s).collect();
        for w in splits.windows(2) {
            assert!(
                w[1] <= w[0],
                "{:?} p={}: split moved deeper with weaker edge: {splits:?}",
                c.network,
                c.probability
            );
        }
    }
    // 2) 4G reaches cloud-only at gamma no larger than 3G (per p < 1).
    let first_cloud = |net: Profile, p: f64| {
        curves
            .iter()
            .find(|c| c.network == net && c.probability == p)
            .unwrap()
            .points
            .iter()
            .find(|&&(_, s, _)| s == 0)
            .map(|&(g, _, _)| g)
    };
    for &p in &[0.2, 0.5, 0.8] {
        if let (Some(g3), Some(g4)) = (
            first_cloud(Profile::ThreeG, p),
            first_cloud(Profile::FourG, p),
        ) {
            assert!(g4 <= g3 + 1e-9, "p={p}: 4G {g4} vs 3G {g3}");
        }
    }
    println!("\nall Fig. 5 shape checks PASSED");
    Ok(())
}
