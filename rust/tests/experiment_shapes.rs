//! Paper shape claims as fast tests (no artifacts needed): the Fig. 4/5
//! qualitative statements must hold for any B-AlexNet-like profile, so we
//! assert them on a synthetic profile shaped like the measured one.

use branchyserve::experiments::{ablation, fig4, fig5};
use branchyserve::model::{BranchDesc, BranchyNetDesc};
use branchyserve::network::bandwidth::{LinkModel, Profile};
use branchyserve::timing::DelayProfile;

/// B-AlexNet-shaped fixture: real alpha profile, plausible cloud times.
fn fixture() -> (BranchyNetDesc, DelayProfile) {
    let desc = BranchyNetDesc {
        stage_names: [
            "conv1", "conv2", "conv3", "conv4", "conv5", "fc1", "fc2", "fc3",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
        stage_out_bytes: vec![57_600, 18_816, 25_088, 25_088, 3_456, 1_024, 512, 8],
        input_bytes: 12_288,
        branches: vec![BranchDesc {
            after_stage: 1,
            exit_prob: 0.0,
        }],
    };
    let profile = DelayProfile::from_cloud_times(
        vec![8.4e-4, 1.2e-3, 3.3e-4, 4.5e-4, 3.6e-4, 5.2e-5, 4.0e-5, 4.7e-5],
        4.0e-4,
        10.0,
    );
    (desc, profile)
}

#[test]
fn fig4_optimal_time_non_increasing_in_probability() {
    let (desc, profile) = fixture();
    for c in fig4::run(&desc, &profile, 21, 1e-9) {
        for w in c.points.windows(2) {
            assert!(
                w[1].1 <= w[0].1 + 1e-12,
                "gamma={} {:?}: E[T] rose with p",
                c.gamma,
                c.network
            );
        }
    }
}

#[test]
fn fig4_bandwidth_sensitivity_ordering() {
    let (desc, profile) = fixture();
    let curves = fig4::run(&desc, &profile, 21, 1e-9);
    let red = |net: Profile| {
        curves
            .iter()
            .find(|c| c.gamma == 10.0 && c.network == net)
            .unwrap()
            .reduction_pct()
    };
    assert!(red(Profile::ThreeG) > red(Profile::FourG));
    assert!(red(Profile::FourG) > red(Profile::WiFi));
}

#[test]
fn fig4_probability_one_equalizes_at_strong_edge() {
    let (desc, profile) = fixture();
    let curves = fig4::run(&desc, &profile, 11, 1e-9);
    let last = |net: Profile| {
        curves
            .iter()
            .find(|c| c.gamma == 10.0 && c.network == net)
            .unwrap()
            .points
            .last()
            .unwrap()
            .1
    };
    let (a, b, c) = (
        last(Profile::ThreeG),
        last(Profile::FourG),
        last(Profile::WiFi),
    );
    assert!((a - b).abs() < 1e-12 && (b - c).abs() < 1e-12);
}

#[test]
fn fig4_weak_edge_has_cloud_only_plateau() {
    // Paper Fig. 4(b): for gamma=100 and fast networks, low probabilities
    // give a constant (cloud-only) inference time.
    let (desc, profile) = fixture();
    let curves = fig4::run(&desc, &profile, 21, 1e-9);
    let wifi = curves
        .iter()
        .find(|c| c.gamma == 1000.0 && c.network == Profile::WiFi)
        .unwrap();
    assert!(wifi
        .points
        .windows(2)
        .take(5)
        .all(|w| (w[0].1 - w[1].1).abs() < 1e-15));
    assert_eq!(wifi.points[0].2, 0, "low-p optimum should be cloud-only");
}

#[test]
fn fig5_partition_marches_to_input_with_gamma() {
    let (desc, profile) = fixture();
    let gammas = fig5::gamma_grid(30, 5000.0);
    for c in fig5::run(&desc, &profile, &gammas, 1e-9) {
        let splits: Vec<usize> = c.points.iter().map(|&(_, s, _)| s).collect();
        for w in splits.windows(2) {
            assert!(
                w[1] <= w[0],
                "{:?} p={}: {splits:?}",
                c.network,
                c.probability
            );
        }
    }
}

#[test]
fn fig5_fourg_switches_to_cloud_before_threeg() {
    let (desc, profile) = fixture();
    let gammas = fig5::gamma_grid(40, 10_000.0);
    let curves = fig5::run(&desc, &profile, &gammas, 1e-9);
    let first_cloud = |net: Profile, p: f64| {
        curves
            .iter()
            .find(|c| c.network == net && c.probability == p)
            .unwrap()
            .points
            .iter()
            .find(|&&(_, s, _)| s == 0)
            .map(|&(g, _, _)| g)
    };
    for &p in &[0.2, 0.5, 0.8] {
        if let (Some(g3), Some(g4)) = (
            first_cloud(Profile::ThreeG, p),
            first_cloud(Profile::FourG, p),
        ) {
            assert!(g4 <= g3 + 1e-9, "p={p}: 4G {g4} vs 3G {g3}");
        }
    }
}

#[test]
fn fig5_probability_affects_the_chosen_layer() {
    // The paper's headline: probability is a real factor in partitioning.
    // Somewhere in the gamma sweep, p=0.2 and p=1.0 must disagree.
    let (desc, profile) = fixture();
    let gammas = fig5::gamma_grid(40, 5000.0);
    let curves = fig5::run(&desc, &profile, &gammas, 1e-9);
    let of = |p: f64| {
        curves
            .iter()
            .find(|c| c.network == Profile::ThreeG && c.probability == p)
            .unwrap()
    };
    let low = of(0.2);
    let high = of(1.0);
    assert!(
        low.points
            .iter()
            .zip(&high.points)
            .any(|(a, b)| a.1 != b.1),
        "probability never changed the partition choice"
    );
}

#[test]
fn ablation_strategy_gap_positive_somewhere() {
    // Modeling the branch must actually help in at least one scenario
    // (otherwise the paper's contribution is vacuous on this profile).
    let (desc, profile) = fixture();
    let gaps = ablation::strategy_gap(&desc, &profile, &[0.5, 0.9], &[10.0, 100.0]);
    assert!(
        gaps.iter().any(|g| g.max_speedup() > 1.05),
        "no scenario showed >5% gain over the best baseline"
    );
}

#[test]
fn ablation_epsilon_insensitive() {
    let (mut desc, profile) = fixture();
    desc.branches[0].exit_prob = 0.6;
    for net in Profile::ALL {
        let res = ablation::epsilon_sensitivity(
            &desc,
            &profile,
            LinkModel::from_profile(net),
            &[1e-12, 1e-9, 1e-6],
        );
        assert!(res.windows(2).all(|w| w[0].1 == w[1].1), "{net:?}: {res:?}");
    }
}

#[test]
fn ablation_branch_placement_finds_an_optimum() {
    let (desc, profile) = fixture();
    let res = ablation::branch_placement(
        &desc,
        &profile,
        LinkModel::from_profile(Profile::ThreeG),
        0.6,
    );
    assert_eq!(res.len(), desc.num_stages() - 1);
    let best = res
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    assert!(best.1.is_finite() && best.1 > 0.0);
}
