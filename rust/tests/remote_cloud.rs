//! Multi-host cloud offload over loopback: coordinators whose cloud
//! workers speak INFER_PARTIAL to a [`CloudStageServer`] on a second
//! listener. Proves (a) the edge half transfers exactly at the planned
//! split, (b) end-to-end results are bit-identical to the in-process
//! sim backend, (c) a dead remote falls back to local execution without
//! dropping a single request, (d) the fleet's `cloud_addr` wiring
//! spans two listeners end to end, and (e) the quantized (q8) pipelined
//! path answers like the in-process oracle while shipping strictly
//! fewer bytes. Runs entirely on the simulated runtime — no artifacts
//! required.
//!
//! [`CloudStageServer`]: branchyserve::server::CloudStageServer

use std::sync::Arc;
use std::time::Duration;

use branchyserve::config::settings::Strategy;
use branchyserve::coordinator::{CloudExec, Coordinator, CoordinatorConfig};
use branchyserve::fleet::{ClassProfile, ClassRegistry, Fleet, FleetConfig};
use branchyserve::model::Manifest;
use branchyserve::network::{BandwidthTrace, Channel, WireEncoding};
use branchyserve::partition::PartitionPlan;
use branchyserve::runtime::{HostTensor, InferenceEngine};
use branchyserve::server::protocol::BRANCH_GATED;
use branchyserve::server::{
    Client, CloudStageServer, RemoteCloudConfig, RemoteCloudEngine, Response, Server,
};
use branchyserve::timing::DelayProfile;

const N_STAGES: usize = 3;

fn manifest() -> Manifest {
    Manifest::synthetic_sim("sim-remote", vec![4], &[16, 8, 2], 1, 2, vec![1, 2, 4, 8]).unwrap()
}

fn channel() -> Arc<Channel> {
    Arc::new(Channel::new(BandwidthTrace::constant(100.0), 0.0, 0.0, 1).simulated_time())
}

fn plan_at(m: &Manifest, split: usize) -> PartitionPlan {
    PartitionPlan::from_split(split, 0.0, Strategy::ShortestPath, &m.to_desc(0.5))
}

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        entropy_threshold: 0.0, // nothing exits: every sample crosses the wire
        batch_timeout: Duration::from_millis(1),
        ..Default::default()
    }
}

fn images(n: usize) -> Vec<HostTensor> {
    (0..n)
        .map(|i| {
            let base = i as f32 * 0.37 - 1.0;
            HostTensor::new(vec![4], vec![base, base * -0.5, 0.25 + base, 1.0 - base]).unwrap()
        })
        .collect()
}

/// The acceptance test: edge coordinator + remote cloud-stage server on
/// a second loopback listener produce bit-identical results to the
/// in-process sim pipeline, with every transfer observed at the planned
/// split and none anywhere else.
#[test]
fn loopback_cloud_matches_in_process_bit_for_bit() {
    let m = manifest();
    let split = 2; // branch (after stage 1) active; cloud runs stage 3

    let css = Arc::new(CloudStageServer::new(
        InferenceEngine::open_sim(m.clone(), "par-srv").unwrap(),
    ));
    let cloud_listener = Server::new(css.clone()).start(0).unwrap();

    let remote = Arc::new(RemoteCloudEngine::new(RemoteCloudConfig::new(
        cloud_listener.addr().to_string(),
    )));
    let remote_coord = Coordinator::start(
        InferenceEngine::open_sim(m.clone(), "par-edge").unwrap(),
        CloudExec::Remote {
            remote: remote.clone(),
            fallback: InferenceEngine::open_sim(m.clone(), "par-fb").unwrap(),
            chain: None,
        },
        channel(),
        plan_at(&m, split),
        cfg(),
    );

    // Oracle: the ordinary in-process pipeline, same plan and threshold.
    let local_coord = Coordinator::start(
        InferenceEngine::open_sim(m.clone(), "par-ledge").unwrap(),
        InferenceEngine::open_sim(m.clone(), "par-lcloud").unwrap(),
        channel(),
        plan_at(&m, split),
        cfg(),
    );

    for img in images(12) {
        let r = remote_coord.infer_sync(img.clone()).unwrap();
        let l = local_coord.infer_sync(img).unwrap();
        assert_eq!(r.class, l.class, "remote and in-process classes diverged");
        assert_eq!(
            r.entropy.to_bits(),
            l.entropy.to_bits(),
            "gate entropies diverged"
        );
        assert!(!r.exited_early() && !l.exited_early());
        assert!(r.transfer_s > 0.0, "sample never crossed the uplink");
    }

    // Transfers happened exactly at the planned split — nowhere else.
    let splits = css.splits_served();
    assert!(splits[split] > 0, "{splits:?}");
    for (s, &count) in splits.iter().enumerate() {
        if s != split {
            assert_eq!(count, 0, "unexpected transfer cut at split {s}: {splits:?}");
        }
    }
    let (batches, samples, gated, _, errors) = css.counters();
    assert_eq!(samples, 12);
    assert_eq!(gated, batches, "split 2 > branch 1: every batch is pre-gated");
    assert_eq!(errors, 0);

    let rm = remote_coord.shutdown();
    assert_eq!(rm.completed, 12);
    assert_eq!(rm.remote_batches, batches);
    assert_eq!(rm.remote_fallbacks, 0, "no fallback on a healthy loopback");
    let stats = remote.stats();
    assert_eq!(stats.requests, batches);
    assert_eq!(stats.failures, 0);
    assert!(stats.connects >= 1);

    local_coord.shutdown();
    cloud_listener.stop();
}

/// The quantized wire path end to end: a coordinator shipping q8
/// activations through the pipelined client to a loopback cloud stage
/// answers exactly like the unquantized in-process oracle (the q8 step
/// on these activations is ~1/510 of their range — far inside the sim
/// model's logit gaps), while every frame reaches the server encoded
/// and both sides' byte counters agree.
#[test]
fn loopback_q8_pipeline_matches_in_process_oracle() {
    let m = manifest();
    let split = 2; // branch (after stage 1) active; cloud runs stage 3

    let css = Arc::new(CloudStageServer::new(
        InferenceEngine::open_sim(m.clone(), "q8-srv").unwrap(),
    ));
    let cloud_listener = Server::new(css.clone()).start(0).unwrap();

    let remote = Arc::new(RemoteCloudEngine::new(RemoteCloudConfig {
        encoding: WireEncoding::Q8,
        ..RemoteCloudConfig::new(cloud_listener.addr().to_string())
    }));
    let remote_coord = Coordinator::start(
        InferenceEngine::open_sim(m.clone(), "q8-edge").unwrap(),
        CloudExec::Remote {
            remote: remote.clone(),
            fallback: InferenceEngine::open_sim(m.clone(), "q8-fb").unwrap(),
            chain: None,
        },
        channel(),
        plan_at(&m, split),
        CoordinatorConfig {
            wire_encoding: WireEncoding::Q8,
            ..cfg()
        },
    );
    let local_coord = Coordinator::start(
        InferenceEngine::open_sim(m.clone(), "q8-ledge").unwrap(),
        InferenceEngine::open_sim(m.clone(), "q8-lcloud").unwrap(),
        channel(),
        plan_at(&m, split),
        cfg(),
    );

    for img in images(12) {
        let r = remote_coord.infer_sync(img.clone()).unwrap();
        let l = local_coord.infer_sync(img).unwrap();
        assert_eq!(r.class, l.class, "q8 flipped a class the oracle disagrees on");
        // The branch gate runs on the edge, before the codec: its
        // entropy never sees quantization and stays bit-identical.
        assert_eq!(
            r.entropy.to_bits(),
            l.entropy.to_bits(),
            "gate entropies diverged"
        );
        assert!(!r.exited_early() && !l.exited_early());
    }

    // Every batch reached the server as q8; none as raw or q4, and the
    // rejected-batch counter stayed untouched.
    let [enc_raw, enc_q8, enc_q4] = css.served_by_encoding();
    assert_eq!((enc_raw, enc_q4), (0, 0), "unexpected encodings served");
    assert!(enc_q8 >= 1);
    let (_, samples, _, _, errors) = css.counters();
    assert_eq!(samples, 12);
    assert_eq!(errors, 0);

    // Both ends of the wire agree on what crossed it. The server books
    // an exchange's bytes *after* writing its response, so its counters
    // may trail the client's read by one scheduling beat — poll briefly
    // before comparing.
    let stats = remote.stats();
    assert_eq!(stats.failures, 0);
    assert!(stats.bytes_sent > 0 && stats.bytes_received > 0);
    let deadline = std::time::Instant::now() + Duration::from_secs(2);
    loop {
        let (srv_in, srv_out) = css.bytes_io();
        if (srv_in, srv_out) == (stats.bytes_sent, stats.bytes_received)
            || std::time::Instant::now() > deadline
        {
            assert_eq!(
                (srv_in, srv_out),
                (stats.bytes_sent, stats.bytes_received),
                "client/server byte accounting diverged"
            );
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    let rm = remote_coord.shutdown();
    assert_eq!(rm.completed, 12);
    assert_eq!(rm.remote_fallbacks, 0, "no fallback on a healthy loopback");
    // Transfer accounting charges the q8 wire size: 8 codec-header
    // bytes + 1 byte/elem instead of 4 bytes/elem of raw f32.
    assert!(rm.transferred_bytes > 0);
    assert!(
        rm.transferred_bytes < 12 * 8 * 4,
        "q8 accounting should undercut raw f32: {}",
        rm.transferred_bytes
    );

    local_coord.shutdown();
    cloud_listener.stop();
}

/// A dead cloud address: every request still completes, served by the
/// local fallback engine with answers identical to a pure in-process
/// pipeline, and the fallbacks are counted.
#[test]
fn dead_cloud_falls_back_to_local_execution() {
    let m = manifest();
    // Port 1 on loopback refuses immediately; short backoff keeps the
    // test brisk while still exercising the fast-fail path.
    let remote = Arc::new(RemoteCloudEngine::new(RemoteCloudConfig {
        backoff_initial: Duration::from_millis(20),
        ..RemoteCloudConfig::new("127.0.0.1:1")
    }));
    let coord = Coordinator::start(
        InferenceEngine::open_sim(m.clone(), "fb-edge").unwrap(),
        CloudExec::Remote {
            remote: remote.clone(),
            fallback: InferenceEngine::open_sim(m.clone(), "fb-cloud").unwrap(),
            chain: None,
        },
        channel(),
        plan_at(&m, 0), // cloud-only: every sample depends on the fallback
        cfg(),
    );
    let local = Coordinator::start(
        InferenceEngine::open_sim(m.clone(), "fb-ledge").unwrap(),
        InferenceEngine::open_sim(m.clone(), "fb-lcloud").unwrap(),
        channel(),
        plan_at(&m, 0),
        cfg(),
    );

    for img in images(6) {
        let r = coord.infer_sync(img.clone()).unwrap();
        let l = local.infer_sync(img).unwrap();
        assert_eq!(r.class, l.class, "fallback answer diverged from local");
        // Nothing crossed the wire and no simulated delay was slept:
        // a fallback sample must not report a phantom transfer.
        assert_eq!(r.transfer_s, 0.0, "{r:?}");
    }
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 6, "a dead cloud must not drop requests");
    assert_eq!(snap.remote_batches, 0);
    assert!(snap.remote_fallbacks >= 1, "{snap:?}");
    assert_eq!(snap.remote_fallbacks, snap.cloud_batches);
    assert!(remote.stats().failures >= 1);
    local.shutdown();
}

/// Raw wire-level INFER_PARTIAL against the cloud listener, plus the
/// rejection paths: a suffix-less split gets an ERROR frame (connection
/// stays usable), and an edge-facing backend refuses partials.
#[test]
fn wire_partial_roundtrip_and_rejections() {
    let m = manifest();
    let css = Arc::new(CloudStageServer::new(
        InferenceEngine::open_sim(m.clone(), "wire-srv").unwrap(),
    ));
    let handle = Server::new(css.clone()).start(0).unwrap();
    let mut client = Client::connect(handle.addr()).unwrap();
    client.ping().unwrap();

    // A batch of 2 stage-1 activations, computed on an oracle engine.
    let probe = InferenceEngine::open_sim(m.clone(), "wire-probe").unwrap();
    let x = HostTensor::new(vec![2, 4], vec![0.1, 0.9, -0.2, 0.8, 0.5, 0.5, 0.5, 0.5]).unwrap();
    let acts = probe.run_stages(1, 1, &x).unwrap();
    match client.infer_partial(1, BRANCH_GATED, acts.clone()).unwrap() {
        Response::PartialResult { samples, cloud_s } => {
            assert_eq!(samples.len(), 2);
            assert!(cloud_s >= 0.0);
            let out = probe.run_stages(2, N_STAGES, &acts).unwrap();
            let want = InferenceEngine::argmax_classes(&out);
            for (s, w) in samples.iter().zip(&want) {
                assert_eq!(s.class as usize, *w);
                assert!(!s.exited, "suffix-only server never gates");
            }
        }
        other => panic!("unexpected {other:?}"),
    }

    // split = N leaves no suffix: ERROR frame, connection survives.
    match client
        .infer_partial(N_STAGES as u32, BRANCH_GATED, HostTensor::zeros(vec![1, 2]))
        .unwrap()
    {
        Response::Error(msg) => assert!(msg.contains("no cloud suffix"), "{msg}"),
        other => panic!("unexpected {other:?}"),
    }
    client.ping().unwrap();
    handle.stop();

    // An edge-facing backend (a coordinator) refuses INFER_PARTIAL.
    let edge_coord = Arc::new(Coordinator::start(
        InferenceEngine::open_sim(m.clone(), "wire-edge").unwrap(),
        InferenceEngine::open_sim(m.clone(), "wire-cloud").unwrap(),
        channel(),
        plan_at(&m, N_STAGES),
        cfg(),
    ));
    let edge_handle = Server::new(edge_coord).start(0).unwrap();
    let mut client = Client::connect(edge_handle.addr()).unwrap();
    match client
        .infer_partial(1, BRANCH_GATED, HostTensor::zeros(vec![1, 16]))
        .unwrap()
    {
        Response::Error(msg) => {
            assert!(msg.contains("does not serve partial"), "{msg}")
        }
        other => panic!("unexpected {other:?}"),
    }
    edge_handle.stop();
}

/// The two-listener fleet deployment: wire client → edge TCP front-end
/// → fleet shard → INFER_PARTIAL over loopback → cloud-stage listener.
#[test]
fn fleet_cloud_addr_offloads_over_the_wire() {
    let m = manifest();
    let css = Arc::new(CloudStageServer::new(
        InferenceEngine::open_sim(m.clone(), "fl-srv").unwrap(),
    ));
    let cloud_listener = Server::new(css.clone()).start(0).unwrap();

    let profile = DelayProfile::from_cloud_times(vec![1e-4; N_STAGES], 2e-5, 50.0);
    let mc = m.clone();
    let fleet = Arc::new(
        Fleet::start(
            // An effectively free uplink plans cloud-only: every sample
            // crosses both listeners.
            ClassRegistry::single(ClassProfile::custom("fast", 100_000.0, 0.0).unwrap()),
            &m,
            &profile,
            FleetConfig {
                cloud_addr: Some(cloud_listener.addr().to_string()),
                entropy_threshold: 0.0,
                batch_timeout: Duration::from_millis(1),
                real_time_channel: false,
                ..Default::default()
            },
            move |label| {
                Ok((
                    InferenceEngine::open_sim(mc.clone(), &format!("{label}-e"))?,
                    InferenceEngine::open_sim(mc.clone(), &format!("{label}-c"))?,
                ))
            },
        )
        .unwrap(),
    );
    let class = fleet.class_by_name("fast").unwrap();
    assert!(fleet.plan_of(class).unwrap().is_cloud_only());

    let edge_listener = Server::new(fleet.clone()).start(0).unwrap();
    let mut client = Client::connect(edge_listener.addr()).unwrap();
    for img in images(6) {
        match client.infer(img).unwrap() {
            Response::Result { class, .. } => assert!(class < 2),
            other => panic!("unexpected {other:?}"),
        }
    }
    drop(client);

    let stats = fleet.remote_stats().expect("cloud_addr was configured");
    assert!(stats.requests >= 1);
    assert_eq!(stats.failures, 0);
    assert!(css.splits_served()[0] > 0, "cloud-only cuts ship the raw input");

    let report = fleet.report();
    assert_eq!(report.total.completed, 6);
    assert!(report.total.remote_batches >= 1);
    assert_eq!(report.total.remote_fallbacks, 0);
    assert!(report.total.transferred_bytes > 0);

    edge_listener.stop();
    cloud_listener.stop();
}
