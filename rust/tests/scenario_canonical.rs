//! The canonical scenarios under `scenarios/` replayed end to end
//! against the real fleet: every SLO check passes, and two runs with
//! the same seed emit bit-identical benchmark JSON once the only
//! intentionally nondeterministic field (`"wall"`) is stripped.

use std::path::{Path, PathBuf};

use branchyserve::config::json::Json;
use branchyserve::scenario::{self, ScenarioOutcome, ScenarioSpec};

fn scenario_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("scenarios")
        .join(format!("{name}.toml"))
}

/// Serialize a run's JSON with the `"wall"` object removed — the
/// determinism contract is bit-identity over everything else.
fn deterministic_form(json: &Json) -> String {
    match json.clone() {
        Json::Obj(mut map) => {
            map.remove("wall");
            Json::Obj(map).to_string_pretty()
        }
        other => panic!("scenario JSON root must be an object, got {other:?}"),
    }
}

/// Run a canonical scenario twice with its file seed: assert every SLO
/// check passed and both runs agree bitwise, then hand back the first
/// outcome for scenario-specific assertions.
fn run_canonical(name: &str) -> ScenarioOutcome {
    let spec = ScenarioSpec::load(&scenario_path(name)).unwrap();
    let first = scenario::run(&spec, None).unwrap();
    for c in &first.checks {
        assert!(c.pass, "{name}: SLO check '{}' failed: {}", c.name, c.detail);
    }
    assert!(first.passed);

    let second = scenario::run(&spec, None).unwrap();
    assert_eq!(
        deterministic_form(&first.json),
        deterministic_form(&second.json),
        "{name}: two same-seed runs must be bit-identical modulo \"wall\""
    );
    first
}

fn total(outcome: &ScenarioOutcome, key: &str) -> f64 {
    outcome
        .json
        .get("totals")
        .and_then(|t| t.get(key))
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("missing totals.{key}"))
}

#[test]
fn diurnal_ramps_deterministically_and_records_the_budget_denial() {
    let o = run_canonical("diurnal");
    // The peak must actually exercise the fleet, not tiptoe around it.
    assert!(total(&o, "offered") > 10_000.0);
    assert_eq!(total(&o, "accepted"), total(&o, "completed"));
}

#[test]
fn flash_crowd_sheds_load_at_the_class_ceiling() {
    let o = run_canonical("flash_crowd");
    assert!(total(&o, "rejected") > 0.0, "a flash crowd must overload admission");
    // Shed or not, the real ledger balances.
    assert_eq!(total(&o, "accepted"), total(&o, "completed"));
}

#[test]
fn link_churn_moves_the_split_and_back() {
    let o = run_canonical("link_churn");
    let splits: Vec<f64> = o
        .json
        .get("classes")
        .and_then(Json::as_arr)
        .and_then(|cs| cs[0].get("splits"))
        .and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .map(|pair| {
                    pair.as_arr()
                        .and_then(|p| p[1].as_f64())
                        .expect("split entries are [t, split] pairs")
                })
                .collect()
        })
        .expect("classes[0].splits");
    assert!(
        splits.len() >= 3,
        "expected edge -> cloud -> edge split trajectory, got {splits:?}"
    );
}

#[test]
fn cloud_brownout_falls_back_without_dropping_anything() {
    let o = run_canonical("cloud_brownout");
    assert!(
        total(&o, "cloud_fallbacks") > 0.0,
        "a brownout with no remote->local fallbacks never browned out"
    );
    assert_eq!(total(&o, "rejected"), 0.0);
    assert_eq!(total(&o, "offered"), total(&o, "completed"));
}

#[test]
fn tier_brownout_degrades_to_direct_without_dropping_anything() {
    let o = run_canonical("tier_brownout");
    assert!(
        total(&o, "chain_fallbacks") > 0.0,
        "a tier brownout with no chain->direct degrades never lost its head"
    );
    assert_eq!(total(&o, "rejected"), 0.0);
    assert_eq!(total(&o, "offered"), total(&o, "completed"));
    // The class routes through the chain: its report carries the full
    // cut vector, and cuts[0] is the split the twin priced.
    let cuts: Vec<f64> = o
        .json
        .get("classes")
        .and_then(Json::as_arr)
        .and_then(|cs| cs[0].get("cuts"))
        .and_then(Json::as_arr)
        .map(|arr| arr.iter().map(|c| c.as_f64().unwrap()).collect())
        .expect("classes[0].cuts must be present for a chain class");
    assert_eq!(cuts.len(), 2, "K=3 chain solves two cut points, got {cuts:?}");
}

#[test]
fn exit_drift_feeds_the_estimator() {
    let o = run_canonical("exit_drift");
    let obs = o
        .json
        .get("classes")
        .and_then(Json::as_arr)
        .and_then(|cs| cs[0].get("estimator_observations"))
        .and_then(Json::as_f64)
        .expect("classes[0].estimator_observations");
    assert!(obs >= 200.0, "estimator consumed only {obs} gate observations");
}

#[test]
fn a_different_seed_is_a_different_run() {
    let spec = ScenarioSpec::load(&scenario_path("link_churn")).unwrap();
    let a = scenario::run(&spec, Some(1)).unwrap();
    let b = scenario::run(&spec, Some(2)).unwrap();
    assert_eq!(a.seed, 1);
    assert_eq!(b.seed, 2);
    assert_ne!(
        deterministic_form(&a.json),
        deterministic_form(&b.json),
        "different seeds must draw different arrival streams"
    );
}
