//! Scenario DSL parsing and validation: well-formed files parse into
//! the expected spec, and every malformed shape is rejected with an
//! error that names the offending entry.

use branchyserve::scenario::{EventKind, ScenarioSpec};

/// A minimal valid scenario; tests splice extra tables onto it.
const BASE: &str = r#"
[scenario]
name = "unit"
duration_s = 10.0

[[link_class]]
name = "4g"

[[workload]]
class = "4g"
rate_rps = 5.0
"#;

fn parse(extra: &str) -> anyhow::Result<ScenarioSpec> {
    ScenarioSpec::parse_str(&format!("{BASE}{extra}"))
}

fn err_of(extra: &str) -> String {
    match parse(extra) {
        Ok(_) => panic!("expected a validation error, scenario parsed:\n{extra}"),
        Err(e) => format!("{e:#}"),
    }
}

#[test]
fn minimal_scenario_parses_with_defaults() {
    let spec = parse("").unwrap();
    assert_eq!(spec.name, "unit");
    assert_eq!(spec.duration_s, 10.0);
    assert_eq!(spec.tick_ms, 20.0);
    assert_eq!(spec.window_s, 1.0);
    assert_eq!(spec.seed, 42);
    assert!(!spec.loopback_cloud);
    assert_eq!(spec.workloads.len(), 1);
    assert_eq!(spec.workloads[0].class1_fraction, 0.5);
    assert!(spec.events.is_empty());
    // The default SLO still checks the ledger.
    assert!(spec.slo.zero_drops);
    assert!(spec.slo.p99_ms.is_none());
}

#[test]
fn events_parse_into_kinds_in_order() {
    let spec = parse(
        r#"
[[event]]
at_s = 1.0
kind = "set_rate"
class = "4g"
rate_rps = 50.0

[[event]]
at_s = 2.0
kind = "ramp_rate"
class = "4g"
rate_rps = 10.0
over_s = 3.0

[[event]]
at_s = 6.0
kind = "set_bandwidth"
class = "4g"
mbps = 0.8

[[event]]
at_s = 7.0
kind = "set_exit_bias"
class = "4g"
class1_fraction = 0.9
"#,
    )
    .unwrap();
    let kinds: Vec<&str> = spec.events.iter().map(|e| e.kind.name()).collect();
    assert_eq!(kinds, ["set_rate", "ramp_rate", "set_bandwidth", "set_exit_bias"]);
    assert!(matches!(
        &spec.events[1].kind,
        EventKind::RampRate { over_s, .. } if *over_s == 3.0
    ));
}

#[test]
fn unknown_event_kind_is_named_with_the_known_list() {
    let e = err_of(
        r#"
[[event]]
at_s = 1.0
kind = "set_weather"
"#,
    );
    assert!(e.contains("event[0]") && e.contains("set_weather"), "{e}");
    assert!(e.contains("known kinds") && e.contains("ramp_rate"), "{e}");
}

#[test]
fn event_missing_required_key_is_rejected() {
    let e = err_of(
        r#"
[[event]]
at_s = 1.0
kind = "set_rate"
class = "4g"
"#,
    );
    assert!(e.contains("event[0]") && e.contains("rate_rps"), "{e}");
}

#[test]
fn out_of_order_timestamps_are_rejected() {
    let e = err_of(
        r#"
[[event]]
at_s = 5.0
kind = "set_rate"
class = "4g"
rate_rps = 50.0

[[event]]
at_s = 2.0
kind = "set_rate"
class = "4g"
rate_rps = 10.0
"#,
    );
    assert!(e.contains("event[1]") && e.contains("out of order"), "{e}");
}

#[test]
fn event_beyond_duration_is_rejected() {
    let e = err_of(
        r#"
[[event]]
at_s = 11.0
kind = "set_rate"
class = "4g"
rate_rps = 1.0
"#,
    );
    assert!(e.contains("outside") && e.contains("10"), "{e}");
}

#[test]
fn unknown_class_names_are_rejected_everywhere() {
    // In an event...
    let e = err_of(
        r#"
[[event]]
at_s = 1.0
kind = "set_rate"
class = "5g"
rate_rps = 1.0
"#,
    );
    assert!(e.contains("unknown link class '5g'"), "{e}");
    assert!(e.contains("4g"), "should list configured classes: {e}");

    // ...in a workload...
    let e = ScenarioSpec::parse_str(
        r#"
[scenario]
name = "unit"
duration_s = 10.0

[[link_class]]
name = "4g"

[[workload]]
class = "lte"
rate_rps = 5.0
"#,
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("unknown link class 'lte'"), "{e:#}");

    // ...and in the SLO block.
    let e = err_of(
        r#"
[slo]
expect_split_change = "5g"
"#,
    );
    assert!(e.contains("expect_split_change") && e.contains("5g"), "{e}");
}

#[test]
fn reassign_to_self_is_rejected() {
    let e = err_of(
        r#"
[[event]]
at_s = 1.0
kind = "reassign"
from = "4g"
to = "4g"
fraction = 0.5
"#,
    );
    assert!(e.contains("itself"), "{e}");
}

#[test]
fn cloud_events_require_loopback_cloud() {
    let e = err_of(
        r#"
[[event]]
at_s = 1.0
kind = "cloud_down"
"#,
    );
    assert!(e.contains("loopback_cloud"), "{e}");
}

#[test]
fn overlapping_brownout_windows_are_rejected() {
    let e = ScenarioSpec::parse_str(
        r#"
[scenario]
name = "unit"
duration_s = 10.0
loopback_cloud = true

[[link_class]]
name = "4g"

[[workload]]
class = "4g"
rate_rps = 5.0

[[event]]
at_s = 1.0
kind = "cloud_down"

[[event]]
at_s = 2.0
kind = "cloud_down"
"#,
    )
    .unwrap_err();
    let e = format!("{e:#}");
    assert!(e.contains("overlapping brownout"), "{e}");
    assert!(e.contains("1 s"), "should name when the open window began: {e}");
}

#[test]
fn cloud_up_without_a_brownout_is_rejected() {
    let e = ScenarioSpec::parse_str(
        r#"
[scenario]
name = "unit"
duration_s = 10.0
loopback_cloud = true

[[link_class]]
name = "4g"

[[workload]]
class = "4g"
rate_rps = 5.0

[[event]]
at_s = 1.0
kind = "cloud_up"
"#,
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("without a preceding cloud_down"), "{e:#}");
}

#[test]
fn a_closed_brownout_can_reopen() {
    let spec = ScenarioSpec::parse_str(
        r#"
[scenario]
name = "unit"
duration_s = 10.0
loopback_cloud = true

[[link_class]]
name = "4g"

[[workload]]
class = "4g"
rate_rps = 5.0

[[event]]
at_s = 1.0
kind = "cloud_down"

[[event]]
at_s = 2.0
kind = "cloud_up"

[[event]]
at_s = 3.0
kind = "cloud_down"
"#,
    )
    .unwrap();
    assert_eq!(spec.events.len(), 3);
}

#[test]
fn duplicate_workloads_are_rejected() {
    let e = err_of(
        r#"
[[workload]]
class = "4g"
rate_rps = 1.0
"#,
    );
    assert!(e.contains("duplicate workload"), "{e}");
}

#[test]
fn a_scenario_needs_a_workload_and_a_link_class() {
    let e = ScenarioSpec::parse_str(
        r#"
[scenario]
name = "unit"
duration_s = 10.0

[[link_class]]
name = "4g"
"#,
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("[[workload]]"), "{e:#}");

    let e = ScenarioSpec::parse_str(
        r#"
[scenario]
name = "unit"
duration_s = 10.0
"#,
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("[[link_class]]"), "{e:#}");
}

#[test]
fn bad_scenario_scalars_are_rejected() {
    // Name must be filesystem-safe.
    let e = ScenarioSpec::parse_str(
        r#"
[scenario]
name = "Has Spaces"
duration_s = 10.0

[[link_class]]
name = "4g"

[[workload]]
class = "4g"
rate_rps = 5.0
"#,
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("a-z0-9_-"), "{e:#}");

    // Window shorter than a tick cannot accumulate anything.
    let e = ScenarioSpec::parse_str(
        r#"
[scenario]
name = "unit"
duration_s = 10.0
tick_ms = 50.0
window_s = 0.01

[[link_class]]
name = "4g"

[[workload]]
class = "4g"
rate_rps = 5.0
"#,
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("window_s"), "{e:#}");
}

#[test]
fn slo_expectations_require_their_mechanisms() {
    // Budget denial without a budget.
    let e = err_of(
        r#"
[slo]
expect_budget_denial = true
"#,
    );
    assert!(e.contains("max_total_shards"), "{e}");

    // Fallbacks without a loopback cloud.
    let e = err_of(
        r#"
[slo]
expect_fallbacks = true
"#,
    );
    assert!(e.contains("loopback_cloud"), "{e}");

    // Estimator floor without online estimation.
    let e = err_of(
        r#"
[slo]
min_estimator_observations = 10
"#,
    );
    assert!(e.contains("online_estimation"), "{e}");

    // Ceiling expectations without an autoscaler.
    let e = err_of(
        r#"
[slo]
expect_max_shards_reached = "4g"
"#,
    );
    assert!(e.contains("autoscale"), "{e}");
}

#[test]
fn the_fleet_half_is_read_as_ordinary_settings() {
    let spec = parse(
        r#"
[edge]
gamma = 33.0

[serve]
queue_capacity = 16
"#,
    )
    .unwrap();
    assert_eq!(spec.settings.edge.gamma, 33.0);
    assert_eq!(spec.settings.serve.queue_capacity, 16);
    assert_eq!(spec.class_names(), ["4g"]);
}

#[test]
fn canonical_scenarios_on_disk_all_validate() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("scenarios");
    let mut found = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        found += 1;
        let spec = ScenarioSpec::load(&path)
            .unwrap_or_else(|e| panic!("{} failed to validate: {e:#}", path.display()));
        assert_eq!(
            format!("{}.toml", spec.name),
            path.file_name().unwrap().to_str().unwrap(),
            "scenario name must match its file name"
        );
    }
    assert!(found >= 5, "expected the five canonical scenarios, found {found}");
}

/// A minimal valid K=3 chain scenario; tier tests splice events onto it.
const CHAIN_BASE: &str = r#"
[scenario]
name = "unit"
duration_s = 10.0
loopback_cloud = true

[[tier]]
addr = "127.0.0.1:7901"
uplink_mbps = 1000.0
rtt_ms = 1.0
compute_scale = 4.0

[[tier]]
addr = "127.0.0.1:7902"

[[link_class]]
name = "4g"

[[workload]]
class = "4g"
rate_rps = 5.0
"#;

#[test]
fn tier_events_parse_on_a_chain_and_pair_up() {
    let spec = ScenarioSpec::parse_str(&format!(
        "{CHAIN_BASE}
[[event]]
at_s = 1.0
kind = \"tier_down\"

[[event]]
at_s = 2.0
kind = \"tier_up\"
"
    ))
    .unwrap();
    let kinds: Vec<&str> = spec.events.iter().map(|e| e.kind.name()).collect();
    assert_eq!(kinds, ["tier_down", "tier_up"]);
    assert_eq!(spec.settings.tiers.len(), 2);
}

#[test]
fn tier_events_require_a_chain() {
    // tier_down on a plain two-tier fleet has no chain head to lose.
    let e = err_of(
        r#"
[[event]]
at_s = 1.0
kind = "tier_down"
"#,
    );
    assert!(e.contains("[[tier]]"), "{e}");
}

#[test]
fn overlapping_tier_brownouts_are_rejected() {
    let e = ScenarioSpec::parse_str(&format!(
        "{CHAIN_BASE}
[[event]]
at_s = 1.0
kind = \"tier_down\"

[[event]]
at_s = 2.0
kind = \"tier_down\"
"
    ))
    .unwrap_err();
    let e = format!("{e:#}");
    assert!(e.contains("overlapping tier-brownout"), "{e}");
    assert!(e.contains("1 s"), "should name when the open window began: {e}");
}

#[test]
fn tier_up_without_a_tier_brownout_is_rejected() {
    let e = ScenarioSpec::parse_str(&format!(
        "{CHAIN_BASE}
[[event]]
at_s = 1.0
kind = \"tier_up\"
"
    ))
    .unwrap_err();
    assert!(
        format!("{e:#}").contains("without a preceding tier_down"),
        "{e:#}"
    );
}

#[test]
fn a_chain_scenario_requires_the_loopback_cloud() {
    let e = err_of(
        r#"
[[tier]]
addr = "127.0.0.1:7901"
uplink_mbps = 1000.0
rtt_ms = 1.0

[[tier]]
addr = "127.0.0.1:7902"
"#,
    );
    assert!(e.contains("loopback_cloud"), "{e}");
}

#[test]
fn expect_chain_fallbacks_requires_a_chain() {
    let e = err_of(
        r#"
[slo]
expect_chain_fallbacks = true
"#,
    );
    assert!(e.contains("[[tier]]"), "{e}");
}
