//! Coordinator end-to-end over the real artifacts: early-exit semantics,
//! plan realization (edge-only / cloud-only / mid split), metric
//! consistency, backpressure, live re-planning. Requires `make artifacts`.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use branchyserve::config::settings::{Flavor, Strategy};
use branchyserve::coordinator::{Coordinator, CoordinatorConfig};
use branchyserve::model::Manifest;
use branchyserve::network::bandwidth::LinkModel;
use branchyserve::network::{BandwidthTrace, Channel};
use branchyserve::partition::PartitionPlan;
use branchyserve::runtime::InferenceEngine;
use branchyserve::workload::ImageSource;

fn setup() -> Option<(Manifest, InferenceEngine, InferenceEngine)> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    let manifest = Manifest::load(dir).unwrap();
    let edge = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "t-edge").unwrap();
    let cloud = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "t-cloud").unwrap();
    Some((manifest, edge, cloud))
}

fn plan_for(manifest: &Manifest, split: usize, strategy: Strategy) -> PartitionPlan {
    PartitionPlan::from_split(split, 0.0, strategy, &manifest.to_desc(0.5))
}

fn fast_channel() -> Arc<Channel> {
    // Simulated-time channel: accounts delay but never sleeps.
    Arc::new(Channel::new(BandwidthTrace::constant(1000.0), 0.0, 0.0, 0).simulated_time())
}

fn coordinator_with(
    manifest: &Manifest,
    edge: InferenceEngine,
    cloud: InferenceEngine,
    split: usize,
    threshold: f32,
) -> Coordinator {
    Coordinator::start(
        edge,
        cloud,
        fast_channel(),
        plan_for(manifest, split, Strategy::ShortestPath),
        CoordinatorConfig {
            entropy_threshold: threshold,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 512,
            ..Default::default()
        },
    )
}

#[test]
fn mid_split_with_exits_classifies_correctly() {
    let Some((manifest, edge, cloud)) = setup() else {
        return;
    };
    // Split after stage 2: branch (after stage 1) is active.
    let c = coordinator_with(&manifest, edge, cloud, 2, 0.4);
    let mut source = ImageSource::new(31);
    let mut correct = 0;
    let mut exits = 0;
    let n = 32;
    let mut pend = Vec::new();
    for _ in 0..n {
        let (img, label) = source.sample();
        let (_, rx) = c.submit(img).unwrap();
        pend.push((rx, label));
    }
    for (rx, label) in pend {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        if r.class == label {
            correct += 1;
        }
        if r.exited_early() {
            exits += 1;
            assert!(r.entropy < 0.4, "exited with entropy {}", r.entropy);
            assert_eq!(r.transfer_s, 0.0, "exited samples must not transfer");
            assert_eq!(r.cloud_s, 0.0);
        } else {
            assert!(
                r.entropy.is_nan() || r.entropy >= 0.4,
                "non-exited sample with entropy {}",
                r.entropy
            );
        }
    }
    assert!(correct >= n * 9 / 10, "accuracy {correct}/{n}");
    assert!(exits > 0, "threshold 0.4 should exit many clean samples");
    let m = c.shutdown();
    assert_eq!(m.completed, n as u64);
    assert_eq!(m.edge_exits, exits as u64);
    assert_eq!(m.completed, m.edge_exits + m.cloud_completions);
}

#[test]
fn cloud_only_plan_never_exits_early() {
    let Some((manifest, edge, cloud)) = setup() else {
        return;
    };
    let c = coordinator_with(&manifest, edge, cloud, 0, 0.69);
    let mut source = ImageSource::new(32);
    for _ in 0..8 {
        let (img, _) = source.sample();
        let r = c.infer_sync(img).unwrap();
        assert!(!r.exited_early());
        assert!(r.entropy.is_nan(), "cloud-only must not evaluate the branch");
    }
    let m = c.shutdown();
    assert_eq!(m.edge_exits, 0);
    assert!(m.transferred_bytes > 0, "cloud-only must upload inputs");
}

#[test]
fn edge_only_plan_completes_without_transfer() {
    let Some((manifest, edge, cloud)) = setup() else {
        return;
    };
    let n_stages = manifest.num_stages();
    let c = coordinator_with(&manifest, edge, cloud, n_stages, 0.2);
    let mut source = ImageSource::new(33);
    for _ in 0..8 {
        let (img, _) = source.sample();
        let r = c.infer_sync(img).unwrap();
        assert_eq!(r.transfer_s, 0.0);
        assert_eq!(r.cloud_s, 0.0);
    }
    let m = c.shutdown();
    assert_eq!(m.transferred_bytes, 0);
    assert_eq!(m.cloud_completions, 0);
}

#[test]
fn threshold_extremes_control_exit_rate() {
    let Some((manifest, edge, cloud)) = setup() else {
        return;
    };
    // Threshold ~ln2: every sample exits at the branch.
    let c = coordinator_with(&manifest, edge.clone(), cloud.clone(), 3, 0.6932);
    let mut source = ImageSource::new(34);
    for _ in 0..8 {
        let (img, _) = source.sample();
        assert!(c.infer_sync(img).unwrap().exited_early());
    }
    c.shutdown();

    // Threshold 0: nothing exits.
    let c = coordinator_with(&manifest, edge, cloud, 3, 0.0);
    let mut source = ImageSource::new(35);
    for _ in 0..8 {
        let (img, _) = source.sample();
        assert!(!c.infer_sync(img).unwrap().exited_early());
    }
    c.shutdown();
}

#[test]
fn backpressure_rejects_over_capacity() {
    let Some((manifest, edge, cloud)) = setup() else {
        return;
    };
    let c = Coordinator::start(
        edge,
        cloud,
        fast_channel(),
        plan_for(&manifest, 2, Strategy::ShortestPath),
        CoordinatorConfig {
            entropy_threshold: 0.4,
            max_batch: 8,
            batch_timeout: Duration::from_millis(50),
            queue_capacity: 4,
            ..Default::default()
        },
    );
    let mut source = ImageSource::new(36);
    let mut rejected = 0;
    let mut pend = Vec::new();
    for _ in 0..64 {
        let (img, _) = source.sample();
        match c.submit(img) {
            Ok((_, rx)) => pend.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "tiny queue must shed load");
    for rx in pend {
        let _ = rx.recv_timeout(Duration::from_secs(60));
    }
    let m = c.shutdown();
    assert!(m.rejected >= rejected as u64);
}

#[test]
fn live_replanning_switches_path() {
    let Some((manifest, edge, cloud)) = setup() else {
        return;
    };
    let c = coordinator_with(&manifest, edge, cloud, 0, 0.5);
    let mut source = ImageSource::new(37);
    let (img, _) = source.sample();
    let r = c.infer_sync(img.clone()).unwrap();
    assert!(!r.exited_early()); // cloud-only

    // Switch to edge-only live.
    c.set_plan(plan_for(&manifest, manifest.num_stages(), Strategy::EdgeOnly));
    let r2 = c.infer_sync(img).unwrap();
    assert_eq!(r2.transfer_s, 0.0, "after replan, no transfer expected");
    c.shutdown();
}

#[test]
fn batched_submissions_all_answered_once() {
    let Some((manifest, edge, cloud)) = setup() else {
        return;
    };
    let c = coordinator_with(&manifest, edge, cloud, 2, 0.35);
    let mut source = ImageSource::new(38);
    let mut pend = Vec::new();
    for _ in 0..50 {
        let (img, _) = source.sample();
        pend.push(c.submit(img).unwrap());
    }
    let mut seen = std::collections::HashSet::new();
    for (id, rx) in pend {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.id, id);
        assert!(seen.insert(r.id), "duplicate response for {id}");
        // Exactly one response per request:
        assert!(rx.try_recv().is_err());
    }
    let m = c.shutdown();
    assert_eq!(m.completed, 50);
}

#[test]
fn channel_link_model_consistency() {
    // The link the planner assumed and the channel's current link agree.
    let link = LinkModel::new(5.85, 0.01);
    let ch = Channel::from_link(link);
    let now = ch.current_link();
    assert!((now.uplink_mbps - 5.85).abs() < 1e-12);
    assert!((now.rtt_s - 0.01).abs() < 1e-12);
}
