//! The core paper invariant, property-tested: the shortest-path solver
//! over G'_BDNN returns exactly the minimum of the expected-inference-
//! time estimator (Eq. 6) over all splits — i.e. BranchyNet partitioning
//! really is reducible to shortest path. Cross-checked against brute
//! force on thousands of random BranchyNets, plus baseline dominance and
//! partition-set sanity.

use branchyserve::config::settings::Strategy;
use branchyserve::graph::{bellman_ford, dijkstra};
use branchyserve::model::synthetic;
use branchyserve::network::bandwidth::LinkModel;
use branchyserve::partition::{baselines, brute, gprime, plan::PartitionPlan, solver};
use branchyserve::testing::{property, Gen};
use branchyserve::timing::Estimator;

const EPS: f64 = 1e-9;

fn random_link(g: &mut Gen) -> LinkModel {
    LinkModel::new(g.f64_in(0.05, 100.0), g.f64_in(0.0, 0.05))
}

#[test]
fn solver_matches_brute_force_on_random_branchynets() {
    property("solver == brute force", 500, |g| {
        let n = g.usize_in(1, 24);
        let desc = synthetic::random_desc(g, n, 4);
        let gamma = g.f64_in(1.0, 2000.0);
        let profile = synthetic::random_profile(g, &desc, gamma);
        let link = random_link(g);
        let paper_mode = g.bool(0.5);

        let plan = solver::solve(&desc, &profile, link, EPS, paper_mode);
        let est = Estimator::new(&desc, &profile, link);
        let est = if paper_mode { est.paper_mode() } else { est };
        let best = (0..=n)
            .map(|s| est.expected_time(s))
            .fold(f64::INFINITY, f64::min);

        // Equal up to fp noise + the epsilon tie-breaker.
        let tol = EPS + 1e-9 * best.abs().max(1.0) + 1e-12;
        assert!(
            (plan.expected_time_s - best).abs() <= tol,
            "solver {} vs brute {best} (n={n}, gamma={gamma:.1}, paper={paper_mode})",
            plan.expected_time_s
        );
        // And the reported split must actually achieve the reported time.
        let achieved = est.expected_time(plan.split_after);
        assert!(
            (achieved - plan.expected_time_s).abs() <= tol,
            "plan reports {} but split {} achieves {achieved}",
            plan.expected_time_s,
            plan.split_after
        );
    });
}

#[test]
fn gprime_shortest_path_agrees_with_bellman_ford() {
    property("dijkstra == bellman-ford on G'", 200, |g| {
        let n = g.usize_in(1, 16);
        let desc = synthetic::random_desc(g, n, 3);
        let gamma_ = g.f64_in(1.0, 500.0);
        let profile = synthetic::random_profile(g, &desc, gamma_);
        let link = random_link(g);
        let gp = gprime::build(&desc, &profile, link, EPS, g.bool(0.5));
        let a = dijkstra::shortest_path(&gp.graph, gp.input, gp.output).unwrap();
        let b = bellman_ford::shortest_path(&gp.graph, gp.input, gp.output).unwrap();
        assert!(
            (a.cost - b.cost).abs() < 1e-12 * a.cost.max(1.0) + 1e-15,
            "dijkstra {} vs bellman-ford {}",
            a.cost,
            b.cost
        );
    });
}

#[test]
fn gprime_is_always_a_dag_with_bounded_size() {
    property("G' structure", 200, |g| {
        let n = g.usize_in(1, 20);
        let desc = synthetic::random_desc(g, n, 5);
        let profile = synthetic::random_profile(g, &desc, 10.0);
        let gp = gprime::build(&desc, &profile, LinkModel::new(1.0, 0.0), EPS, false);
        assert!(gp.graph.is_dag());
        let m = desc.branches.len();
        // 2 virtual + 2n edge + m branch + (m+1)(n+1) cloud upper bound.
        let bound = 2 + 2 * n + m + (m + 1) * (n + 1);
        assert!(
            gp.graph.len() <= bound,
            "{} nodes > bound {bound} (n={n}, m={m})",
            gp.graph.len()
        );
    });
}

#[test]
fn neurosurgeon_never_beats_solver_and_matches_at_p0() {
    property("baseline dominance", 300, |g| {
        let n = g.usize_in(1, 16);
        let mut desc = synthetic::random_desc(g, n, 3);
        let gamma_ = g.f64_in(1.0, 1000.0);
        let profile = synthetic::random_profile(g, &desc, gamma_);
        let link = random_link(g);

        let opt = solver::solve(&desc, &profile, link, EPS, true);
        let ns = baselines::neurosurgeon(&desc, &profile, link, true);
        assert!(
            opt.expected_time_s <= ns.expected_time_s + 1e-9,
            "neurosurgeon beat the solver: {} < {}",
            ns.expected_time_s,
            opt.expected_time_s
        );

        // With all probabilities zeroed they coincide.
        for b in &mut desc.branches {
            b.exit_prob = 0.0;
        }
        let opt0 = solver::solve(&desc, &profile, link, EPS, true);
        let ns0 = baselines::neurosurgeon(&desc, &profile, link, true);
        assert!(
            (opt0.expected_time_s - ns0.expected_time_s).abs() <= EPS + 1e-12,
            "p=0: solver {} vs neurosurgeon {}",
            opt0.expected_time_s,
            ns0.expected_time_s
        );
    });
}

#[test]
fn static_strategies_bracket_the_solver() {
    property("edge/cloud-only dominance", 300, |g| {
        let n = g.usize_in(1, 16);
        let desc = synthetic::random_desc(g, n, 3);
        let gamma_ = g.f64_in(1.0, 1000.0);
        let profile = synthetic::random_profile(g, &desc, gamma_);
        let link = random_link(g);
        let est = Estimator::new(&desc, &profile, link).paper_mode();
        let opt = brute::solve(&est);
        let edge = baselines::static_split(&est, n, Strategy::EdgeOnly);
        let cloud = baselines::static_split(&est, 0, Strategy::CloudOnly);
        assert!(opt.expected_time_s <= edge.expected_time_s + 1e-12);
        assert!(opt.expected_time_s <= cloud.expected_time_s + 1e-12);
    });
}

#[test]
fn partition_sets_are_a_partition() {
    property("V_e and V_c partition V", 300, |g| {
        let n = g.usize_in(1, 20);
        let desc = synthetic::random_desc(g, n, 4);
        let profile = synthetic::random_profile(g, &desc, 10.0);
        let plan = solver::solve(&desc, &profile, random_link(g), EPS, true);
        let (v_e, v_c) = plan.partition_sets(&desc);
        let stages_e: Vec<&String> = v_e.iter().filter(|s| !s.starts_with("b@")).collect();
        assert_eq!(stages_e.len() + v_c.len(), n);
        for s in &stages_e {
            assert!(!v_c.contains(s), "{s} on both sides");
        }
        // Branch markers only appear for branches strictly before the cut.
        for b in v_e.iter().filter(|s| s.starts_with("b@")) {
            let pos: usize = b[2..].parse().unwrap();
            assert!(pos < plan.split_after);
        }
    });
}

#[test]
fn probability_extremes_degenerate_correctly() {
    property("p extremes", 200, |g| {
        let n = g.usize_in(2, 12);
        let mut desc = synthetic::random_desc(g, n, 1);
        if desc.branches.is_empty() {
            return;
        }
        let gamma_ = g.f64_in(1.0, 100.0);
        let profile = synthetic::random_profile(g, &desc, gamma_);
        let link = random_link(g);

        // p = 0: identical to the branch-free network.
        desc.branches[0].exit_prob = 0.0;
        let with_branch = solver::solve(&desc, &profile, link, EPS, true);
        let mut no_branch = desc.clone();
        no_branch.branches.clear();
        let plain = solver::solve(&no_branch, &profile, link, EPS, true);
        assert!(
            (with_branch.expected_time_s - plain.expected_time_s).abs() <= EPS + 1e-12,
            "p=0 should equal branch-free: {} vs {}",
            with_branch.expected_time_s,
            plain.expected_time_s
        );

        // p = 1: expected time never exceeds the edge prefix through the
        // branch (everything afterwards is free).
        desc.branches[0].exit_prob = 1.0;
        let k = desc.branches[0].after_stage;
        let plan1 = solver::solve(&desc, &profile, link, EPS, true);
        let prefix: f64 = profile.t_edge[..k].iter().sum();
        assert!(
            plan1.expected_time_s <= prefix + EPS + 1e-12,
            "p=1 plan {} exceeds edge prefix {prefix}",
            plan1.expected_time_s
        );
    });
}

#[test]
fn plan_with_strategy_dispatch() {
    let mut g = Gen::replay(1);
    let desc = synthetic::random_desc(&mut g, 6, 2);
    let profile = synthetic::random_profile(&mut g, &desc, 50.0);
    let link = LinkModel::new(5.85, 0.0);
    for st in [
        Strategy::ShortestPath,
        Strategy::BruteForce,
        Strategy::Neurosurgeon,
        Strategy::EdgeOnly,
        Strategy::CloudOnly,
    ] {
        let plan: PartitionPlan =
            branchyserve::partition::plan_with_strategy(st, &desc, &profile, link, EPS, true);
        assert_eq!(plan.strategy, st);
        assert!(plan.expected_time_s.is_finite());
    }
}
