//! Property tests over the hand-rolled substrates (DESIGN.md §3): the
//! JSON codec, the wire protocol and the batcher must survive randomized
//! round-trips and concurrent stress — they replace battle-tested crates,
//! so they get fuzz-style coverage here.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use branchyserve::config::json::Json;
use branchyserve::coordinator::batcher::Batcher;
use branchyserve::runtime::HostTensor;
use branchyserve::server::protocol::{read_frame, write_frame, Request, Response};
use branchyserve::testing::{property, Gen};

// ---------------------------------------------------------------- JSON

fn random_json(g: &mut Gen, depth: usize) -> Json {
    let kind = if depth == 0 {
        g.usize_in(0, 3)
    } else {
        g.usize_in(0, 5)
    };
    match kind {
        0 => Json::Null,
        1 => Json::Bool(g.bool(0.5)),
        2 => {
            // Finite, round-trippable numbers.
            let v = g.f64_in(-1e12, 1e12);
            Json::Num(if g.bool(0.5) { v.round() } else { v })
        }
        3 => Json::Str(random_string(g)),
        4 => Json::Arr((0..g.usize_in(0, 5)).map(|_| random_json(g, depth - 1)).collect()),
        _ => {
            let mut m = BTreeMap::new();
            for _ in 0..g.usize_in(0, 5) {
                m.insert(random_string(g), random_json(g, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

fn random_string(g: &mut Gen) -> String {
    let len = g.usize_in(0, 12);
    (0..len)
        .map(|_| {
            match g.usize_in(0, 6) {
                0 => '"',
                1 => '\\',
                2 => '\n',
                3 => 'é',
                4 => '😀',
                _ => (b'a' + g.usize_in(0, 25) as u8) as char,
            }
        })
        .collect()
}

fn json_approx_eq(a: &Json, b: &Json) -> bool {
    match (a, b) {
        (Json::Num(x), Json::Num(y)) => {
            (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1.0)
        }
        (Json::Arr(x), Json::Arr(y)) => {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| json_approx_eq(p, q))
        }
        (Json::Obj(x), Json::Obj(y)) => {
            x.len() == y.len()
                && x.iter()
                    .zip(y)
                    .all(|((k1, v1), (k2, v2))| k1 == k2 && json_approx_eq(v1, v2))
        }
        _ => a == b,
    }
}

#[test]
fn json_roundtrips_random_documents() {
    property("json compact+pretty roundtrip", 300, |g| {
        let doc = random_json(g, 3);
        let compact = Json::parse(&doc.to_string()).unwrap();
        assert!(json_approx_eq(&doc, &compact), "compact: {doc} vs {compact}");
        let pretty = Json::parse(&doc.to_string_pretty()).unwrap();
        assert!(json_approx_eq(&doc, &pretty), "pretty: {doc} vs {pretty}");
    });
}

#[test]
fn json_parser_never_panics_on_garbage() {
    property("json parser totality", 500, |g| {
        let len = g.usize_in(0, 40);
        let garbage: String = (0..len)
            .map(|_| {
                let set = b"{}[]\",:0123456789.eE+-truefalsn \t\n\\u";
                set[g.usize_in(0, set.len() - 1)] as char
            })
            .collect();
        // Must return Ok or Err, never panic.
        let _ = Json::parse(&garbage);
    });
}

// ------------------------------------------------------------ protocol

#[test]
fn protocol_roundtrips_random_tensors() {
    property("INFER roundtrip", 200, |g| {
        let ndims = g.usize_in(1, 4);
        let dims: Vec<usize> = (0..ndims).map(|_| g.usize_in(1, 6)).collect();
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|_| g.f64_in(-1e6, 1e6) as f32).collect();
        let t = HostTensor::new(dims, data).unwrap();
        let req = Request::Infer(t.clone());
        match Request::decode(&req.encode()).unwrap() {
            Request::Infer(back) => assert_eq!(back, t),
            other => panic!("{other:?}"),
        }
    });
}

#[test]
fn protocol_decoder_never_panics_on_random_bytes() {
    property("protocol decode totality", 500, |g| {
        let len = g.usize_in(0, 64);
        let bytes: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
        let _ = Request::decode(&bytes);
        let _ = Response::decode(&bytes);
    });
}

#[test]
fn frame_layer_roundtrips_and_rejects_truncation() {
    property("frame roundtrip", 200, |g| {
        let len = g.usize_in(0, 256);
        let body: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
        let mut buf = Vec::new();
        write_frame(&mut buf, &body).unwrap();
        assert_eq!(
            read_frame(&mut std::io::Cursor::new(buf.clone())).unwrap(),
            body
        );
        // Any strict prefix must fail cleanly.
        if !buf.is_empty() {
            let cut = g.usize_in(0, buf.len() - 1);
            assert!(read_frame(&mut std::io::Cursor::new(&buf[..cut])).is_err());
        }
    });
}

// ------------------------------------------------------------- batcher

#[test]
fn batcher_conserves_items_under_concurrency() {
    // N producers, M consumers: every submitted item is delivered exactly
    // once, no batch exceeds max_batch.
    let batcher: Arc<Batcher<u64>> = Arc::new(Batcher::new(10_000, 7, Duration::from_millis(1)));
    let producers = 4;
    let per_producer = 500u64;

    let mut handles = Vec::new();
    for p in 0..producers {
        let b = batcher.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per_producer {
                b.submit(p * 1_000_000 + i).unwrap();
            }
        }));
    }
    let mut consumers = Vec::new();
    for _ in 0..3 {
        let b = batcher.clone();
        consumers.push(std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(batch) = b.next_batch() {
                assert!(batch.len() <= 7 && !batch.is_empty());
                got.extend(batch);
            }
            got
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // Let consumers drain, then close.
    while !batcher.is_empty() {
        std::thread::sleep(Duration::from_millis(2));
    }
    batcher.close();
    let mut all: Vec<u64> = Vec::new();
    for c in consumers {
        all.extend(c.join().unwrap());
    }
    assert_eq!(all.len() as u64, producers * per_producer);
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len() as u64, producers * per_producer, "duplicates detected");
}
