//! Settings loading: TOML file -> typed Settings, validation failures,
//! CLI integration.

use std::io::Write;

use branchyserve::cli::{Cli, Command, Flag, Parsed};
use branchyserve::config::settings::{Flavor, Settings, Strategy};

fn write_temp(name: &str, content: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("branchyserve_cfg_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(content.as_bytes()).unwrap();
    path
}

#[test]
fn full_config_file_roundtrip() {
    let path = write_temp(
        "full.toml",
        r#"
# serving config for the 3G demo
[model]
artifacts_dir = "artifacts"
flavor = "pl"

[network]
kind = "3g"
uplink_mbps = 1.10
rtt_ms = 35.5

[edge]
gamma = 250

[branch]
entropy_threshold = 0.45
exit_probability = 0.62

[partition]
strategy = "neurosurgeon"
epsilon = 1e-10

[serve]
port = 9099
max_batch = 4
batch_timeout_ms = 1.5
queue_capacity = 64
"#,
    );
    let s = Settings::load(Some(&path)).unwrap();
    assert_eq!(s.model.flavor, Flavor::Pallas);
    assert_eq!(s.network.kind, "3g");
    assert!((s.network.rtt_s - 0.0355).abs() < 1e-12);
    assert_eq!(s.edge.gamma, 250.0);
    assert_eq!(s.branch.exit_probability, Some(0.62));
    assert_eq!(s.partition.strategy, Strategy::Neurosurgeon);
    assert_eq!(s.partition.epsilon, 1e-10);
    assert_eq!(s.serve.port, 9099);
    assert_eq!(s.serve.max_batch, 4);
    assert_eq!(s.serve.queue_capacity, 64);
}

#[test]
fn partial_config_keeps_defaults() {
    let path = write_temp("partial.toml", "[edge]\ngamma = 42\n");
    let s = Settings::load(Some(&path)).unwrap();
    assert_eq!(s.edge.gamma, 42.0);
    // Everything else: defaults.
    let d = Settings::default();
    assert_eq!(s.serve.port, d.serve.port);
    assert_eq!(s.network.uplink_mbps, d.network.uplink_mbps);
}

#[test]
fn invalid_values_rejected_at_load() {
    for (name, body) in [
        ("bad_gamma.toml", "[edge]\ngamma = 0.2\n"),
        ("bad_thr.toml", "[branch]\nentropy_threshold = 3.0\n"),
        ("bad_p.toml", "[branch]\nexit_probability = -0.1\n"),
        ("bad_eps.toml", "[partition]\nepsilon = 0.5\n"),
        ("bad_strategy.toml", "[partition]\nstrategy = \"magic\"\n"),
        ("bad_port.toml", "[serve]\nport = 99999\n"),
        ("bad_toml.toml", "this is not toml"),
    ] {
        let path = write_temp(name, body);
        assert!(Settings::load(Some(&path)).is_err(), "{name} should fail");
    }
}

#[test]
fn fleet_config_file_roundtrip() {
    let path = write_temp(
        "fleet.toml",
        r#"
[fleet]
shards = 3
cloud_workers = 2
routing = "round-robin"

[[link_class]]
name = "3g"

[[link_class]]
name = "4g"

[[link_class]]
name = "wifi"
rtt_ms = 2
"#,
    );
    let s = Settings::load(Some(&path)).unwrap();
    assert_eq!(s.fleet.shards, 3);
    assert_eq!(s.fleet.cloud_workers, 2);
    assert_eq!(s.fleet.routing, "round-robin");
    assert_eq!(s.link_classes.len(), 3);
    assert!((s.link_classes[1].uplink_mbps - 5.85).abs() < 1e-12);
    assert!((s.link_classes[2].rtt_s - 0.002).abs() < 1e-12);

    // And the fleet registry builds straight from it.
    let reg = branchyserve::fleet::ClassRegistry::from_settings(&s.link_classes).unwrap();
    assert_eq!(reg.len(), 3);
    assert!(reg.id_of("wifi").is_some());
}

#[test]
fn fleet_config_validation_names_offending_field() {
    for (name, body, needle) in [
        ("bad_shards.toml", "[fleet]\nshards = 0\n", "fleet.shards"),
        (
            "bad_routing.toml",
            "[fleet]\nrouting = \"psychic\"\n",
            "fleet.routing",
        ),
        (
            "bad_class.toml",
            "[[link_class]]\nname = \"x\"\nuplink_mbps = -1\n",
            "uplink_mbps",
        ),
        (
            "dup_class.toml",
            "[[link_class]]\nname = \"a\"\nuplink_mbps = 1\n\n[[link_class]]\nname = \"a\"\nuplink_mbps = 2\n",
            "link_class[1].name",
        ),
    ] {
        let path = write_temp(name, body);
        let err = Settings::load(Some(&path)).unwrap_err().to_string();
        assert!(err.contains(needle), "{name}: {err}");
    }
}

#[test]
fn missing_file_is_an_error() {
    assert!(Settings::load(Some(std::path::Path::new("/nonexistent/x.toml"))).is_err());
}

#[test]
fn cli_and_config_compose() {
    // Mirror of main.rs's dispatch: config file + flag overrides.
    let path = write_temp("compose.toml", "[edge]\ngamma = 10\n[serve]\nport = 7000\n");
    let cli = Cli {
        program: "t",
        about: "t",
        global_flags: vec![Flag::value("config", "cfg")],
        commands: vec![Command::new("serve", "s").flag(Flag::value("gamma", "g"))],
    };
    let parsed = cli
        .parse(
            ["--config", path.to_str().unwrap(), "serve", "--gamma", "99"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
    let Parsed::Run(inv) = parsed else { panic!() };
    let mut s = Settings::load(inv.get("config").map(std::path::Path::new)).unwrap();
    assert_eq!(s.edge.gamma, 10.0);
    if let Some(g) = inv.get_f64("gamma").unwrap() {
        s.edge.gamma = g;
    }
    assert_eq!(s.edge.gamma, 99.0);
    assert_eq!(s.serve.port, 7000);
}
