//! Fleet integration: per-class partition points executed concurrently,
//! shard routing, zero-traffic metrics hygiene, adaptive per-class
//! replanning, online exit-rate feedback, per-request planning, and the
//! TCP front-end's class tag. Runs entirely on the simulated runtime —
//! no artifacts required.

use std::sync::Arc;
use std::time::{Duration, Instant};

use branchyserve::fleet::{ClassProfile, ClassRegistry, Fleet, FleetConfig, RoutePolicy};
use branchyserve::model::Manifest;
use branchyserve::network::bandwidth::LinkModel;
use branchyserve::network::BandwidthTrace;
use branchyserve::planner::{AdaptiveConfig, EstimatorConfig, Planner};
use branchyserve::runtime::InferenceEngine;
use branchyserve::server::{Response, Server};
use branchyserve::timing::DelayProfile;
use branchyserve::workload::ImageSource;

const N_STAGES: usize = 5;

fn sim_manifest() -> Manifest {
    Manifest::synthetic_sim(
        "sim-fleet-test",
        vec![3, 32, 32],
        &[512, 256, 128, 64, 2],
        1,
        2,
        vec![1, 2, 4, 8],
    )
    .unwrap()
}

fn sim_profile() -> DelayProfile {
    // Edge stage 5 ms, cloud stage 0.1 ms: a starved uplink prefers the
    // edge, a huge one the cloud — by an order of magnitude either way.
    DelayProfile::from_cloud_times(vec![1e-4; N_STAGES], 2e-5, 50.0)
}

fn start_fleet(registry: ClassRegistry, cfg: FleetConfig) -> Fleet {
    let manifest = sim_manifest();
    let profile = sim_profile();
    let m = manifest.clone();
    Fleet::start(registry, &manifest, &profile, cfg, move |label| {
        Ok((
            InferenceEngine::open_sim(m.clone(), &format!("{label}-e"))?,
            InferenceEngine::open_sim(m.clone(), &format!("{label}-c"))?,
        ))
    })
    .unwrap()
}

fn fast_cfg() -> FleetConfig {
    FleetConfig {
        batch_timeout: Duration::from_millis(1),
        real_time_channel: false,
        entropy_threshold: 0.0, // deterministic: nothing exits early
        ..Default::default()
    }
}

fn slow_fast_registry() -> ClassRegistry {
    ClassRegistry::new(vec![
        ClassProfile::custom("slow", 0.05, 0.0).unwrap(),
        ClassProfile::custom("fast", 100_000.0, 0.0).unwrap(),
    ])
    .unwrap()
}

/// The acceptance test: a slow-class and a fast-class request served
/// concurrently execute under *different* partition points, each
/// matching its per-class planner's output.
#[test]
fn concurrent_classes_execute_under_different_partition_points() {
    let fleet = start_fleet(slow_fast_registry(), fast_cfg());
    let slow = fleet.class_by_name("slow").unwrap();
    let fast = fleet.class_by_name("fast").unwrap();

    // Cross-check the active plans against an independently constructed
    // planner (same desc/profile/epsilon the fleet planned with).
    let reference = Planner::new(&sim_manifest().to_desc(0.5), &sim_profile(), 1e-9, false);
    let want_slow = reference.plan_for(LinkModel::try_new(0.05, 0.0).unwrap());
    let want_fast = reference.plan_for(LinkModel::try_new(100_000.0, 0.0).unwrap());

    let slow_plan = fleet.plan_of(slow).unwrap();
    let fast_plan = fleet.plan_of(fast).unwrap();
    assert_eq!(slow_plan.split_after, want_slow.split_after);
    assert_eq!(fast_plan.split_after, want_fast.split_after);
    assert!(slow_plan.is_edge_only(N_STAGES), "{slow_plan:?}");
    assert!(fast_plan.is_cloud_only(), "{fast_plan:?}");
    assert_ne!(slow_plan.split_after, fast_plan.split_after);

    // Interleave submissions so both classes are in flight at once.
    let mut source = ImageSource::new(71);
    let mut pending = Vec::new();
    for _ in 0..8 {
        let (img, _) = source.sample();
        pending.push(("slow", fleet.submit(slow, img.clone()).unwrap()));
        pending.push(("fast", fleet.submit(fast, img).unwrap()));
    }
    for (kind, (_, rx)) in pending {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        match kind {
            // Edge-only execution: nothing crosses the uplink.
            "slow" => {
                assert_eq!(r.transfer_s, 0.0, "slow-class sample paid a transfer");
                assert_eq!(r.cloud_s, 0.0, "slow-class sample paid cloud compute");
            }
            // Cloud-only execution: the raw input was uploaded.
            _ => assert!(r.transfer_s > 0.0, "fast-class sample skipped the uplink"),
        }
    }

    let report = fleet.shutdown();
    assert_eq!(report.total.completed, 16);
    let by_name = |n: &str| {
        report
            .classes
            .iter()
            .find(|c| c.name == n)
            .unwrap_or_else(|| panic!("missing class {n}"))
    };
    let slow_report = by_name("slow");
    let fast_report = by_name("fast");
    assert_eq!(slow_report.aggregate.completed, 8);
    assert_eq!(fast_report.aggregate.completed, 8);
    assert_eq!(slow_report.split_after, want_slow.split_after);
    assert_eq!(fast_report.split_after, want_fast.split_after);
    assert_eq!(slow_report.aggregate.transferred_bytes, 0);
    assert!(fast_report.aggregate.transferred_bytes > 0);
}

#[test]
fn round_robin_routing_spreads_load_across_all_shards() {
    let registry = ClassRegistry::single(ClassProfile::custom("only", 0.05, 0.0).unwrap());
    let fleet = start_fleet(
        registry,
        FleetConfig {
            shards_per_class: 4,
            routing: RoutePolicy::RoundRobin,
            ..fast_cfg()
        },
    );
    let class = fleet.class_by_name("only").unwrap();
    let mut source = ImageSource::new(72);
    let pending: Vec<_> = (0..32)
        .map(|_| fleet.submit(class, source.sample().0).unwrap())
        .collect();
    for (_, rx) in pending {
        rx.recv_timeout(Duration::from_secs(60)).unwrap();
    }
    let report = fleet.shutdown();
    let per_shard: Vec<u64> = report.classes[0].shards.iter().map(|s| s.completed).collect();
    assert_eq!(per_shard.iter().sum::<u64>(), 32);
    assert_eq!(per_shard.len(), 4);
    assert!(
        per_shard.iter().all(|&c| c == 8),
        "round-robin must spread evenly: {per_shard:?}"
    );
}

#[test]
fn idle_fleet_reports_clean_zeros() {
    let fleet = start_fleet(
        slow_fast_registry(),
        FleetConfig {
            shards_per_class: 2,
            ..fast_cfg()
        },
    );
    let report = fleet.report();
    assert_eq!(report.total.completed, 0);
    assert_eq!(report.total.mean_latency_s, 0.0);
    let s = report.summary();
    assert!(!s.contains("NaN"), "{s}");
    let json = report.to_json();
    let v = branchyserve::config::json::Json::parse(&json).unwrap();
    assert_eq!(v.get("completed").unwrap().as_u64(), Some(0));
    assert_eq!(v.get("classes").unwrap().as_arr().unwrap().len(), 2);
    fleet.shutdown();
}

#[test]
fn adaptive_loop_replans_a_class_when_its_uplink_changes() {
    // One class whose uplink goes from starved to effectively free 300ms
    // in: the per-class replan loop must move every shard of the class
    // from edge-only to cloud-only.
    let trace = BandwidthTrace::new(vec![(0.0, 0.05), (0.3, 100_000.0)]).unwrap();
    let registry = ClassRegistry::single(
        ClassProfile::custom("mobile", 0.05, 0.0)
            .unwrap()
            .with_trace(trace),
    );
    let fleet = start_fleet(
        registry,
        FleetConfig {
            shards_per_class: 2,
            adaptive: Some(AdaptiveConfig {
                interval: Duration::from_millis(20),
                min_improvement: 0.01,
                min_dwell: Duration::ZERO,
            }),
            ..fast_cfg()
        },
    );
    let class = fleet.class_by_name("mobile").unwrap();
    assert!(fleet.plan_of(class).unwrap().is_edge_only(N_STAGES));

    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if fleet.plan_of(class).unwrap().is_cloud_only() {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "adaptive loop never switched the class plan"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    let report = fleet.shutdown();
    for (i, shard) in report.classes[0].shards.iter().enumerate() {
        assert!(
            shard.plan_switches >= 1,
            "shard {i} never saw a plan switch"
        );
    }
}

/// Fixture for the exit-feedback test: stage 1's output is expensive to
/// ship (10240 elems = 40960 B) while later activations are small, so
/// with a high exit probability the optimum cuts *after* the branch
/// (split 2: most traffic never pays the transfer), while with a low
/// one it ships the raw input (cloud-only). Exactly the regime where a
/// wrong prior executes the wrong split.
fn feedback_manifest() -> Manifest {
    Manifest::synthetic_sim(
        "sim-feedback",
        vec![3, 32, 32],
        &[10_240, 256, 128, 64, 2],
        1,
        2,
        vec![1, 2, 4, 8],
    )
    .unwrap()
}

fn feedback_profile() -> DelayProfile {
    // Edge stage 10 ms (gamma 100 on 0.1 ms cloud stages), branch eval
    // 2 ms on the edge.
    DelayProfile::from_cloud_times(vec![1e-4; 5], 2e-5, 100.0)
}

/// The exit-rate feedback acceptance test: a class configured with a
/// high exit-probability prior (0.8) plans a mid-network split, but the
/// workload never exits early (entropy threshold 0) — the observed exit
/// rate is 0. The estimator's p̂ must converge down and the class's
/// *executed* partition point must move to the low-p optimum
/// (cloud-only), without adaptive bandwidth replanning being involved.
#[test]
fn online_exit_rate_feedback_moves_the_executed_split() {
    let manifest = feedback_manifest();
    let profile = feedback_profile();
    let link = LinkModel::try_new(5.85, 0.0).unwrap();

    // Preconditions, from an independent planner: the prior plans split
    // 2 (branch active — the gate produces observations), the observed
    // rate plans cloud-only.
    let prior = Planner::new(&manifest.to_desc(0.8), &profile, 1e-9, false);
    let want_prior = prior.plan_for(link);
    assert_eq!(want_prior.split_after, 2, "fixture drifted: {want_prior:?}");
    let want_converged = prior.with_exit_probs(&[0.1]).plan_for(link);
    assert!(want_converged.is_cloud_only(), "{want_converged:?}");

    let m = manifest.clone();
    let fleet = Fleet::start(
        ClassRegistry::single(ClassProfile::custom("mobile", 5.85, 0.0).unwrap()),
        &manifest,
        &profile,
        FleetConfig {
            default_exit_prob: 0.8,
            estimation: Some(EstimatorConfig {
                alpha: 0.25,
                drift_threshold: 0.25,
                min_observations: 8,
            }),
            ..fast_cfg()
        },
        move |label| {
            Ok((
                InferenceEngine::open_sim(m.clone(), &format!("{label}-e"))?,
                InferenceEngine::open_sim(m.clone(), &format!("{label}-c"))?,
            ))
        },
    )
    .unwrap();
    let class = fleet.class_by_name("mobile").unwrap();
    assert_eq!(fleet.plan_of(class).unwrap().split_after, 2);

    // Drive enough non-exiting traffic through the branch gate for the
    // drift gate to fire (min_observations = 8). The rebuild happens
    // synchronously on the edge worker, so by the time the 8th response
    // is back the shard's plan has already moved.
    let mut source = ImageSource::new(74);
    for _ in 0..8 {
        let r = fleet.infer_sync(class, source.sample().0).unwrap();
        assert!(!r.exited_early(), "threshold 0 must never exit");
    }
    let moved = fleet.plan_of(class).unwrap();
    assert!(
        moved.is_cloud_only(),
        "executed split must follow p̂ down: {moved:?}"
    );

    // Post-convergence traffic executes the new split: raw input over
    // the uplink, and the (now inactive) branch never gates it.
    for _ in 0..3 {
        let r = fleet.infer_sync(class, source.sample().0).unwrap();
        assert!(r.transfer_s > 0.0, "cloud-only sample skipped the uplink");
        assert!(r.entropy.is_nan(), "cloud-only sample saw the branch gate");
    }

    let report = fleet.shutdown();
    let c = &report.classes[0];
    assert_eq!(c.split_after, want_converged.split_after);
    let p = &c.planner;
    assert!(p.view_rebuilds >= 1, "no view rebuild recorded: {p:?}");
    assert!(p.cache_invalidations >= 1, "cache survived the swap: {p:?}");
    assert!(
        p.exit_prob_planned < 0.2,
        "planned p still near the prior: {p:?}"
    );
    let p_hat = p.p_hat.expect("estimation was enabled");
    assert!(p_hat < 0.15, "p̂ did not converge toward 0: {p_hat}");
    assert_eq!(p.estimator_observations, 8, "one observation per gated sample");
    // And the JSON surface carries the new observability.
    let json = report.to_json();
    assert!(json.contains("\"p_hat\":"), "{json}");
    assert!(json.contains("\"view_rebuilds\":"), "{json}");
}

/// The per-request planning acceptance test: one class whose uplink
/// trace collapses from starved to effectively free mid-run. With
/// per-request planning on, requests admitted before the flip execute
/// edge-only while requests admitted after it execute cloud-only — with
/// both outstanding at once and the class's *base* plan never moving
/// (no adaptive loop is running; the overrides do all the work).
#[test]
fn per_request_planning_executes_instantaneous_link_splits() {
    let trace = BandwidthTrace::new(vec![(0.0, 0.05), (0.5, 100_000.0)]).unwrap();
    let registry = ClassRegistry::single(
        ClassProfile::custom("mobile", 0.05, 0.0)
            .unwrap()
            .with_trace(trace),
    );
    let fleet = start_fleet(
        registry,
        FleetConfig {
            per_request_planning: true,
            ..fast_cfg()
        },
    );
    let class = fleet.class_by_name("mobile").unwrap();
    let base = fleet.plan_of(class).unwrap();
    assert!(base.is_edge_only(N_STAGES), "{base:?}");

    // Phase 1: starved uplink — per-request plans must keep work local.
    let mut source = ImageSource::new(75);
    let mut slow_pending = Vec::new();
    for _ in 0..4 {
        slow_pending.push(fleet.submit(class, source.sample().0).unwrap());
    }

    // Phase 2: after the trace flips, the *same class* plans cloud-only
    // per request. The slow-phase receivers stay undrained, so both
    // phases' responses are outstanding together.
    std::thread::sleep(Duration::from_millis(700));
    let mut fast_pending = Vec::new();
    for _ in 0..4 {
        fast_pending.push(fleet.submit(class, source.sample().0).unwrap());
    }

    for (_, rx) in slow_pending {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.transfer_s, 0.0, "slow-phase sample paid a transfer");
        assert_eq!(r.cloud_s, 0.0, "slow-phase sample paid cloud compute");
    }
    for (_, rx) in fast_pending {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(r.transfer_s > 0.0, "fast-phase sample skipped the uplink");
    }

    // The base plan never moved: the splits came from request overrides.
    assert!(fleet.plan_of(class).unwrap().is_edge_only(N_STAGES));
    let report = fleet.shutdown();
    let c = &report.classes[0];
    assert_eq!(
        c.aggregate.plan_overrides, 8,
        "every request must carry a per-request plan"
    );
    // Both link regimes hit the planner: at least two distinct buckets.
    assert!(c.planner.cache_misses >= 2, "{:?}", c.planner);
    assert!(c.aggregate.transferred_bytes > 0);
}

/// Exit-rate probing, mechanically: a fast uplink plans cloud-only per
/// request (branch inactive), so with `probe_fraction = 0.5` every
/// second request must be rerouted through the smallest branch-active
/// split — observable as a real entropy in its response — while the
/// rest execute their solved plan untouched.
#[test]
fn probe_fraction_routes_branch_active_overrides() {
    let registry = ClassRegistry::single(ClassProfile::custom("fast", 100_000.0, 0.0).unwrap());
    let fleet = start_fleet(
        registry,
        FleetConfig {
            per_request_planning: true,
            probe_fraction: 0.5,
            ..fast_cfg()
        },
    );
    let class = fleet.class_by_name("fast").unwrap();
    let mut source = ImageSource::new(76);
    let mut gated = 0;
    for _ in 0..8 {
        let r = fleet.infer_sync(class, source.sample().0).unwrap();
        if !r.entropy.is_nan() {
            gated += 1; // only probed samples see the branch gate
        }
        // Probed (split 2) and un-probed (cloud-only) samples both
        // transfer — the probe split is still before the model's end.
        assert!(r.transfer_s > 0.0, "sample skipped the uplink");
    }
    assert_eq!(gated, 4, "every 2nd branch-inactive plan must probe");

    let report = fleet.shutdown();
    let c = &report.classes[0];
    assert_eq!(c.planner.probe_overrides, 4);
    assert_eq!(
        c.aggregate.plan_overrides, 8,
        "probes ride on per-request overrides, they don't add new ones"
    );
    assert!(report.to_json().contains("\"probe_overrides\":4"), "{}", report.to_json());
}

/// The recovery story the ROADMAP asked for: a pessimistic prior plans
/// cloud-only, so the branch gate never fires and p̂ would freeze at
/// the prior forever — but the observed traffic actually exits almost
/// always. Probes route a fraction of requests through a branch-active
/// split, the estimator sees their exits, p̂ recovers *upward*, and the
/// class's executed split moves to the high-p optimum.
#[test]
fn probing_lets_p_hat_recover_upward() {
    let manifest = feedback_manifest();
    let profile = feedback_profile();
    let link = LinkModel::try_new(5.85, 0.0).unwrap();

    // Preconditions from an independent planner: the prior (p = 0.05)
    // plans cloud-only (branch inactive); the true behaviour (p high)
    // plans split 2.
    let prior = Planner::new(&manifest.to_desc(0.05), &profile, 1e-9, false);
    assert!(prior.plan_for(link).is_cloud_only(), "fixture drifted");
    let want = prior.with_exit_probs(&[0.9]).plan_for(link);
    assert_eq!(want.split_after, 2, "fixture drifted: {want:?}");

    let m = manifest.clone();
    let fleet = Fleet::start(
        ClassRegistry::single(ClassProfile::custom("mobile", 5.85, 0.0).unwrap()),
        &manifest,
        &profile,
        FleetConfig {
            default_exit_prob: 0.05,
            entropy_threshold: 10.0, // everything that reaches the gate exits
            per_request_planning: true,
            probe_fraction: 0.25,
            estimation: Some(EstimatorConfig {
                alpha: 0.5,
                drift_threshold: 0.25,
                min_observations: 4,
            }),
            batch_timeout: Duration::from_millis(1),
            real_time_channel: false,
            ..Default::default()
        },
        move |label| {
            Ok((
                InferenceEngine::open_sim(m.clone(), &format!("{label}-e"))?,
                InferenceEngine::open_sim(m.clone(), &format!("{label}-c"))?,
            ))
        },
    )
    .unwrap();
    let class = fleet.class_by_name("mobile").unwrap();
    assert!(fleet.plan_of(class).unwrap().is_cloud_only());

    // 16 serial requests: every 4th branch-inactive plan is probed, its
    // sample exits at the gate, and the 4th observation trips the drift
    // gate (min_observations = 4) — rebuilding the view at p̂ and moving
    // every shard's base plan. The rebuild runs synchronously on the
    // edge worker, so later requests already execute the new split.
    let mut source = ImageSource::new(77);
    let mut exits = 0;
    for _ in 0..16 {
        let r = fleet.infer_sync(class, source.sample().0).unwrap();
        if r.exited_early() {
            exits += 1;
        }
    }
    assert!(exits >= 4, "probes never reached the gate: {exits} exits");
    let moved = fleet.plan_of(class).unwrap();
    assert_eq!(
        moved.split_after, want.split_after,
        "executed split must follow p̂ up: {moved:?}"
    );

    let report = fleet.shutdown();
    let p = &report.classes[0].planner;
    assert!(p.probe_overrides >= 4, "{p:?}");
    assert!(p.view_rebuilds >= 1, "{p:?}");
    let p_hat = p.p_hat.expect("estimation was enabled");
    assert!(p_hat > 0.5, "p̂ did not recover upward: {p_hat}");
    assert!(
        p.exit_prob_planned > 0.5,
        "planned p still near the pessimistic prior: {p:?}"
    );
}

#[test]
fn tcp_front_end_routes_class_tags_to_the_fleet() {
    let fleet = Arc::new(start_fleet(slow_fast_registry(), fast_cfg()));
    let handle = Server::new(fleet.clone()).start(0).unwrap();
    let mut client = branchyserve::server::Client::connect(handle.addr()).unwrap();
    client.ping().unwrap();

    let mut source = ImageSource::new(73);
    let fast_id = fleet.class_by_name("fast").unwrap().0;

    // Tagged: routed to the fast (cloud-only) class.
    let (img, _) = source.sample();
    match client.infer_class(fast_id, img).unwrap() {
        Response::Result { class, .. } => assert!(class < 2),
        other => panic!("unexpected {other:?}"),
    }
    // Untagged legacy INFER: served as class 0.
    let (img, _) = source.sample();
    assert!(matches!(
        client.infer(img).unwrap(),
        Response::Result { .. }
    ));
    // Unknown class tag: an error frame, not a dead connection.
    let (img, _) = source.sample();
    match client.infer_class(9, img).unwrap() {
        Response::Error(msg) => assert!(msg.contains("unknown link class"), "{msg}"),
        other => panic!("unexpected {other:?}"),
    }
    client.ping().unwrap();

    // Fleet metrics over the wire: flat totals + per-class detail.
    match client.call(&branchyserve::server::Request::Metrics).unwrap() {
        Response::Metrics(json) => {
            let v = branchyserve::config::json::Json::parse(&json).unwrap();
            assert_eq!(v.get("completed").unwrap().as_u64(), Some(2));
            let classes = v.get("classes").unwrap().as_arr().unwrap();
            assert_eq!(classes.len(), 2);
        }
        other => panic!("unexpected {other:?}"),
    }
    handle.stop();
}
