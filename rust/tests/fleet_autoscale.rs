//! Autoscaling integration: a sustained single-class burst grows that
//! class to `max_shards` while an idle class shrinks to `min_shards`,
//! with zero dropped requests and answers bit-identical to a fixed-fleet
//! oracle — plus router behavior across resizes (hash remap,
//! least-loaded on the post-resize set, shrink mid-stream). Runs
//! entirely on the simulated runtime.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use branchyserve::coordinator::InferenceResponse;
use branchyserve::fleet::{
    AutoscaleConfig, ClassProfile, ClassRegistry, Fleet, FleetConfig, RoutePolicy,
};
use branchyserve::model::Manifest;
use branchyserve::runtime::{HostTensor, InferenceEngine};
use branchyserve::timing::DelayProfile;
use branchyserve::workload::ImageSource;

const N_STAGES: usize = 5;
/// Per-stage synthetic compute: slow enough that an instantaneous burst
/// builds real queue depth, fast enough to keep the test snappy.
const STAGE_COST: Duration = Duration::from_micros(400);

fn sim_manifest() -> Manifest {
    Manifest::synthetic_sim(
        "sim-autoscale-test",
        vec![3, 32, 32],
        &[512, 256, 128, 64, 2],
        1,
        2,
        vec![1, 2, 4, 8],
    )
    .unwrap()
}

fn sim_profile() -> DelayProfile {
    DelayProfile::from_cloud_times(vec![1e-4; N_STAGES], 2e-5, 50.0)
}

/// A fleet over slow-uplink classes (edge-only plans: nothing crosses
/// the simulated channel, so timing is pure pipeline compute).
fn start_fleet(class_names: &[&str], cfg: FleetConfig) -> Fleet {
    let registry = ClassRegistry::new(
        class_names
            .iter()
            .map(|n| ClassProfile::custom(n, 0.05, 0.0).unwrap())
            .collect(),
    )
    .unwrap();
    let manifest = sim_manifest();
    let profile = sim_profile();
    let m = manifest.clone();
    Fleet::start(registry, &manifest, &profile, cfg, move |label| {
        Ok((
            InferenceEngine::open_sim_with_cost(m.clone(), &format!("{label}-e"), STAGE_COST)?,
            InferenceEngine::open_sim_with_cost(m.clone(), &format!("{label}-c"), STAGE_COST)?,
        ))
    })
    .unwrap()
}

fn fast_cfg() -> FleetConfig {
    FleetConfig {
        batch_timeout: Duration::from_millis(1),
        real_time_channel: false,
        entropy_threshold: 0.0, // deterministic: nothing exits early
        queue_capacity: 8192,   // the burst must queue, never reject
        ..Default::default()
    }
}

/// Tight autoscale knobs so the whole story plays out in well under a
/// second: decisions every ~6 ms, resizes at most every 25 ms.
fn fast_autoscale() -> AutoscaleConfig {
    AutoscaleConfig {
        min_shards: 1,
        max_shards: 4,
        scale_up_depth: 4.0,
        scale_down_depth: 0.5,
        interval: Duration::from_millis(3),
        window: 2,
        cooldown: Duration::from_millis(25),
    }
}

fn recv_all(pending: Vec<(u64, mpsc::Receiver<InferenceResponse>)>) -> Vec<InferenceResponse> {
    pending
        .into_iter()
        .map(|(_, rx)| rx.recv_timeout(Duration::from_secs(60)).expect("request dropped"))
        .collect()
}

/// The acceptance test: burst one class of an elastic two-class fleet.
/// The bursty class must reach `max_shards`, the idle one must settle
/// at `min_shards`, every submitted request must complete, the answers
/// must be bit-identical to a fixed-size oracle fleet fed the same
/// inputs, and the `ScalerStats` counters must reconcile with the
/// observed shard counts.
#[test]
fn burst_grows_to_max_while_idle_shrinks_to_min_with_oracle_identical_results() {
    let acfg = fast_autoscale();
    let (min, max) = (acfg.min_shards, acfg.max_shards);
    let initial = 2;
    let fleet = start_fleet(
        &["burst", "idle"],
        FleetConfig {
            shards_per_class: initial,
            autoscale: Some(acfg),
            ..fast_cfg()
        },
    );
    let burst = fleet.class_by_name("burst").unwrap();
    let idle = fleet.class_by_name("idle").unwrap();
    assert_eq!(fleet.shards_of(burst).unwrap(), initial);
    assert_eq!(fleet.shards_of(idle).unwrap(), initial);

    // Sustained burst: keep queueing work until the class has grown to
    // max_shards (the drained-too-fast case just feeds more), recording
    // every submitted image so the oracle can replay them.
    let mut source = ImageSource::new(80);
    let mut images: Vec<HostTensor> = Vec::new();
    let mut pending = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        for _ in 0..200 {
            let (img, _) = source.sample();
            pending.push(fleet.submit(burst, img.clone()).expect("admission rejected"));
            images.push(img);
        }
        if fleet.shards_of(burst).unwrap() >= max {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "burst class never reached max_shards: {:?}",
            fleet.scaler_stats_of(burst).unwrap()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(fleet.shards_of(burst).unwrap(), max);

    // Every burst request completes — growth never drops work.
    let responses = recv_all(pending);

    // The idle class saw nothing: it must shrink to the floor. (The
    // burst class, now also idle, eventually follows — same rule.)
    let deadline = Instant::now() + Duration::from_secs(30);
    while fleet.shards_of(idle).unwrap() > min || fleet.shards_of(burst).unwrap() > min {
        assert!(
            Instant::now() < deadline,
            "idle classes never shrank to min_shards: idle {:?}, burst {:?}",
            fleet.scaler_stats_of(idle).unwrap(),
            fleet.scaler_stats_of(burst).unwrap()
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    let report = fleet.shutdown();
    let by_name = |n: &str| report.classes.iter().find(|c| c.name == n).unwrap();
    let burst_report = by_name("burst");
    let idle_report = by_name("idle");

    // Scaler counters reconcile exactly with what we observed: the
    // shard count walked initial → max → min, so ups − downs = min −
    // initial, with at least (max − initial) ups along the way.
    for (report, label) in [(burst_report, "burst"), (idle_report, "idle")] {
        let s = &report.scaler;
        assert!(s.enabled);
        assert_eq!((s.min_shards, s.max_shards), (min, max), "{label}");
        assert_eq!(s.current_shards, min, "{label}");
        assert_eq!(s.current_shards, report.shards.len(), "{label}");
        assert_eq!(
            s.scale_ups as i64 - s.scale_downs as i64,
            min as i64 - initial as i64,
            "{label}: ups/downs don't reconcile with the observed sizes: {s:?}"
        );
        assert_eq!(s.retired_shards as u64, s.scale_downs, "{label}");
        assert!(s.last_trigger.is_some(), "{label} resized without a trigger");
    }
    assert!(
        burst_report.scaler.scale_ups >= (max - initial) as u64,
        "{:?}",
        burst_report.scaler
    );
    assert_eq!(idle_report.scaler.scale_ups, 0, "{:?}", idle_report.scaler);

    // Zero requests dropped or rejected, and retired shards' completed
    // work still counts in the class aggregate.
    assert_eq!(burst_report.aggregate.completed as usize, images.len());
    assert_eq!(burst_report.aggregate.rejected, 0);
    assert_eq!(idle_report.aggregate.completed, 0);

    // Oracle: a fixed-size fleet served the identical inputs — every
    // answer (class and entropy) must be bit-identical, elastic or not.
    let oracle = start_fleet(
        &["burst", "idle"],
        FleetConfig {
            shards_per_class: initial,
            ..fast_cfg()
        },
    );
    let oracle_class = oracle.class_by_name("burst").unwrap();
    let oracle_pending: Vec<_> = images
        .iter()
        .map(|img| oracle.submit(oracle_class, img.clone()).unwrap())
        .collect();
    let oracle_responses = recv_all(oracle_pending);
    oracle.shutdown();
    assert_eq!(responses.len(), oracle_responses.len());
    for (i, (got, want)) in responses.iter().zip(&oracle_responses).enumerate() {
        assert_eq!(got.class, want.class, "answer {i} diverged from the oracle");
        assert_eq!(
            got.entropy.to_bits(),
            want.entropy.to_bits(),
            "entropy {i} diverged from the oracle"
        );
    }
}

/// Hash routing across a grow: keys map in-bounds on every set size,
/// stay stable between resizes, and the grown shards actually receive
/// traffic.
#[test]
fn hash_routing_remaps_cleanly_after_grow() {
    let fleet = start_fleet(
        &["only"],
        FleetConfig {
            shards_per_class: 2,
            routing: RoutePolicy::Hash,
            ..fast_cfg()
        },
    );
    let class = fleet.class_by_name("only").unwrap();
    let mut source = ImageSource::new(81);

    let mut pending = Vec::new();
    for key in 0..64u64 {
        pending.push(fleet.submit_keyed(class, key, source.sample().0).unwrap());
    }
    recv_all(pending);

    assert_eq!(fleet.grow_class(class).unwrap(), 3);
    assert_eq!(fleet.grow_class(class).unwrap(), 4);

    let mut pending = Vec::new();
    for key in 0..64u64 {
        pending.push(fleet.submit_keyed(class, key, source.sample().0).unwrap());
    }
    recv_all(pending);

    let report = fleet.shutdown();
    let per_shard: Vec<u64> = report.classes[0].shards.iter().map(|s| s.completed).collect();
    assert_eq!(per_shard.len(), 4);
    assert_eq!(per_shard.iter().sum::<u64>(), 128);
    assert!(
        per_shard[2] + per_shard[3] > 0,
        "64 keys over 4 shards never landed on a grown shard: {per_shard:?}"
    );
    let s = &report.classes[0].scaler;
    assert_eq!((s.scale_ups, s.scale_downs), (2, 0));
    assert_eq!(s.last_trigger.as_deref(), Some("grow: manual"));
}

/// Least-loaded routing reads queue depths from the post-resize set: a
/// burst after growing 1 → 3 must spread across all three shards
/// (depth-ordered picks), not pin to the original shard.
#[test]
fn least_loaded_reads_depths_from_the_post_resize_set() {
    let fleet = start_fleet(
        &["only"],
        FleetConfig {
            shards_per_class: 1,
            routing: RoutePolicy::LeastLoaded,
            ..fast_cfg()
        },
    );
    let class = fleet.class_by_name("only").unwrap();
    assert_eq!(fleet.grow_class(class).unwrap(), 2);
    assert_eq!(fleet.grow_class(class).unwrap(), 3);

    // Instantaneous burst: each submit sees the previous ones' depths,
    // so least-loaded walks the whole (post-grow) set.
    let mut source = ImageSource::new(82);
    let mut pending = Vec::new();
    for _ in 0..48 {
        pending.push(fleet.submit(class, source.sample().0).unwrap());
    }
    recv_all(pending);

    let report = fleet.shutdown();
    let per_shard: Vec<u64> = report.classes[0].shards.iter().map(|s| s.completed).collect();
    assert_eq!(per_shard.iter().sum::<u64>(), 48);
    assert!(
        per_shard.iter().all(|&c| c > 0),
        "least-loaded left a post-grow shard idle: {per_shard:?}"
    );
}

/// Shrinking under live traffic: requests keep flowing while two
/// shrinks retire two of three shards. The admission path holds the
/// shard-set read lock across pick → submit, so no request can be
/// routed into a draining shard — every single one must complete, and
/// the retired shards' work must stay on the books.
#[test]
fn shrink_mid_stream_never_drops_requests() {
    let fleet = std::sync::Arc::new(start_fleet(
        &["only"],
        FleetConfig {
            shards_per_class: 3,
            routing: RoutePolicy::RoundRobin,
            ..fast_cfg()
        },
    ));
    let class = fleet.class_by_name("only").unwrap();

    let submitter = {
        let fleet = fleet.clone();
        std::thread::spawn(move || {
            let mut source = ImageSource::new(83);
            let mut pending = Vec::new();
            for i in 0..300 {
                pending.push(fleet.submit(class, source.sample().0).unwrap());
                if i % 16 == 0 {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            pending
        })
    };

    // Retire two shards while the stream is in flight.
    std::thread::sleep(Duration::from_millis(5));
    assert_eq!(fleet.shrink_class(class).unwrap(), 2);
    assert_eq!(fleet.shrink_class(class).unwrap(), 1);
    // Never below one shard.
    assert!(fleet.shrink_class(class).is_err());

    let pending = submitter.join().unwrap();
    assert_eq!(recv_all(pending).len(), 300);

    let fleet = match std::sync::Arc::try_unwrap(fleet) {
        Ok(f) => f,
        Err(_) => panic!("submitter kept its fleet handle"),
    };
    let report = fleet.shutdown();
    let c = &report.classes[0];
    assert_eq!(c.shards.len(), 1);
    assert_eq!(
        c.aggregate.completed, 300,
        "retired shards' completions fell off the books"
    );
    assert_eq!(c.aggregate.rejected, 0);
    assert_eq!(c.scaler.scale_downs, 2);
    assert_eq!(c.scaler.retired_shards, 2);
    assert_eq!(c.queue_depths.len(), 1);
}
