//! Exhaustive cut-vector oracle for the K-tier chain planner
//! (`Planner::plan_chain`): on nets small enough to brute-force, the
//! solved chain must be **bit-identical** to the argmin over *every*
//! monotone cut vector, where each vector is priced independently fresh
//! in this file — survival chain, edge-cost fold, cloud suffix and
//! encoded alpha table all rebuilt from the public desc/profile
//! primitives, sharing nothing with the planner's core.
//!
//! The oracle replicates the chain's documented tie rule and nothing
//! else: the decision value carries `+epsilon` exactly when the first
//! cut transfers something (`cuts[0] < N`), vectors are enumerated in
//! lexicographic ascending order, and `<=` selection makes the *last*
//! minimizer win — so exact ties resolve toward the lexicographically
//! largest cut vector, the direction the layered DP resolves each of
//! its per-level scans. The grids include the degenerate corners the
//! link model clamps — dead 0 Mbps hops, infinite RTT — plus zero-cost
//! (free relay) middle tiers and exit probabilities at exactly 0 and 1.
//!
//! Two cross-checks ground the oracle itself: its K = 2 pricing must be
//! bit-identical to the standalone `Estimator` (the crate's independent
//! 2-tier cost model), and every enumerated vector's fresh price must
//! agree bit-for-bit with `Planner::chain_expected_time` — the
//! canonical pricing the DP minimizes.

use branchyserve::model::{synthetic, BranchDesc, BranchyNetDesc};
use branchyserve::network::bandwidth::LinkModel;
use branchyserve::network::encoding::WireEncoding;
use branchyserve::planner::{ChainPlan, Planner, TierChain};
use branchyserve::testing::property;
use branchyserve::timing::{DelayProfile, Estimator};

const EPS: f64 = 1e-9;

/// Degenerate corners included in every hop grid: a dead uplink
/// (clamped to the model's 1e-3 Mbps floor), a starved 3G-ish link, the
/// paper's profiles, and an effectively infinite pipe.
const BANDWIDTHS_MBPS: [f64; 6] = [0.0, 1e-3, 0.5, 1.10, 18.80, 1e5];
/// RTT corners, including an infinite RTT (clamped by the link model).
const RTTS_S: [f64; 5] = [0.0, 0.005, 0.1, 60.0, f64::INFINITY];

/// The chain cost model rebuilt from scratch out of the public
/// desc/profile fields — the oracle's own tables. The folds follow the
/// planner's *documented* recurrences (module docs of `planner` and
/// `planner::chain`), not its code: survival chain, then the
/// survival-weighted edge prefix, then (serving mode) the
/// branch-evaluation terms folded after, then the back-to-front cloud
/// suffix and the encoding-mapped alpha table.
struct Tables {
    n: usize,
    /// A(s): survival-weighted edge compute through stage s.
    edge_cost: Vec<f64>,
    /// S(s): survival probability at a cut after stage s.
    surv: Vec<f64>,
    /// C(s): cloud time of stages s+1..=N.
    cloud_suffix: Vec<f64>,
    /// alpha_s under the wire encoding, for cuts 0..N.
    alpha_bytes: Vec<u64>,
}

fn tables(
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    encoding: WireEncoding,
    paper_mode: bool,
) -> Tables {
    let n = desc.num_stages();
    let mut branches: Vec<(usize, f64)> = desc
        .branches
        .iter()
        .map(|b| (b.after_stage, b.exit_prob))
        .collect();
    branches.sort_by_key(|&(pos, _)| pos);

    // survival[j] = P[not exited at any of the first j branches].
    let mut survival = vec![1.0f64];
    for &(_, p) in &branches {
        let last = *survival.last().unwrap();
        survival.push(last * (1.0 - p));
    }
    // Branches *active* at split s: position strictly before s.
    let active_at: Vec<usize> = (0..=n)
        .map(|s| branches.iter().filter(|&&(pos, _)| pos < s).count())
        .collect();

    let mut edge_cost = vec![0.0f64; n + 1];
    for i in 1..=n {
        edge_cost[i] = edge_cost[i - 1] + survival[active_at[i]] * profile.t_edge[i - 1];
    }
    if !paper_mode {
        for s in 0..=n {
            let mut t = edge_cost[s];
            for &reach in &survival[..active_at[s]] {
                t += reach * profile.branch_t_edge;
            }
            edge_cost[s] = t;
        }
    }
    let surv: Vec<f64> = (0..=n).map(|s| survival[active_at[s]]).collect();

    let mut cloud_suffix = vec![0.0f64; n + 1];
    for i in (0..n).rev() {
        cloud_suffix[i] = cloud_suffix[i + 1] + profile.t_cloud[i];
    }
    let alpha_bytes: Vec<u64> = (0..n).map(|s| desc.transfer_wire_bytes(s, encoding)).collect();

    Tables {
        n,
        edge_cost,
        surv,
        cloud_suffix,
        alpha_bytes,
    }
}

/// The documented right fold for tiers `k..`: `scale·(C(from) − C(to))
/// + [to < N]·(hop_k(to) + rest)`.
fn tail_cost(t: &Tables, chain: &TierChain, cuts: &[usize], k: usize, from: usize) -> f64 {
    let kmax = cuts.len();
    let to = if k < kmax { cuts[k] } else { t.n };
    let seg = chain.compute_scale[k - 1] * (t.cloud_suffix[from] - t.cloud_suffix[to]);
    if k < kmax && to < t.n {
        seg + (chain.links[k].transfer_time(t.alpha_bytes[to])
            + tail_cost(t, chain, cuts, k + 1, to))
    } else {
        seg
    }
}

/// `E[T(cuts)]` from the oracle's own tables: `A(c0) + S(c0)·(hop_0(c0)
/// + tail)`, survival factored out of everything past hop 0 because
/// branch gates only ever run on the edge.
fn price(t: &Tables, chain: &TierChain, cuts: &[usize]) -> f64 {
    let c0 = cuts[0];
    let mut out = t.edge_cost[c0];
    if c0 < t.n {
        let surv = t.surv[c0];
        if surv > 0.0 {
            out += surv
                * (chain.links[0].transfer_time(t.alpha_bytes[c0])
                    + tail_cost(t, chain, cuts, 1, c0));
        }
    }
    out
}

/// Every non-decreasing vector of `k` cuts over `0..=n`, visited in
/// lexicographic ascending order.
fn for_each_monotone(n: usize, k: usize, prefix: &mut Vec<usize>, f: &mut dyn FnMut(&[usize])) {
    if prefix.len() == k {
        f(prefix);
        return;
    }
    let lo = prefix.last().copied().unwrap_or(0);
    for c in lo..=n {
        prefix.push(c);
        for_each_monotone(n, k, prefix, f);
        prefix.pop();
    }
}

/// The brute force: price every monotone vector independently, apply
/// the epsilon decision rule (`+epsilon` iff `cuts[0] < N`), select
/// with `<=` over the ascending enumeration so the lexicographically
/// largest minimizer wins — the chain DP's documented tie direction.
fn brute_force_chain(t: &Tables, chain: &TierChain, epsilon: f64) -> (Vec<usize>, f64) {
    let mut best_cuts: Vec<usize> = Vec::new();
    let mut best_model = f64::INFINITY;
    let mut best_decision = f64::INFINITY;
    let mut prefix = Vec::with_capacity(chain.links.len());
    for_each_monotone(t.n, chain.links.len(), &mut prefix, &mut |cuts| {
        let model = price(t, chain, cuts);
        let decision = if cuts[0] < t.n { model + epsilon } else { model };
        if decision <= best_decision {
            best_decision = decision;
            best_model = model;
            best_cuts = cuts.to_vec();
        }
    });
    (best_cuts, best_model)
}

/// Assert `plan_chain` reproduces the oracle exactly: same vector, same
/// expected-time bits, same per-hop wire bytes — and that the plan
/// achieves its reported time through the canonical pricing.
fn assert_matches_oracle(
    planner: &Planner,
    t: &Tables,
    chain: &TierChain,
    epsilon: f64,
    ctx: &str,
) -> ChainPlan {
    // Ground every vector's fresh price in the canonical pricing first:
    // a disagreement here localizes a failure to the cost model rather
    // than the argmin.
    let mut prefix = Vec::with_capacity(chain.links.len());
    for_each_monotone(t.n, chain.links.len(), &mut prefix, &mut |cuts| {
        let fresh = price(t, chain, cuts);
        let canonical = planner.chain_expected_time(chain, cuts);
        assert_eq!(
            fresh.to_bits(),
            canonical.to_bits(),
            "pricing drift at {cuts:?}: fresh {fresh} vs chain_expected_time {canonical} ({ctx})"
        );
    });

    let (want_cuts, want_time) = brute_force_chain(t, chain, epsilon);
    let plan = planner.plan_chain(chain);
    assert_eq!(plan.cuts, want_cuts, "cut vector ({ctx})");
    assert_eq!(
        plan.expected_time_s.to_bits(),
        want_time.to_bits(),
        "expected time {} vs oracle {} ({ctx})",
        plan.expected_time_s,
        want_time
    );
    let want_bytes: Vec<u64> = want_cuts
        .iter()
        .map(|&c| if c == t.n { 0 } else { t.alpha_bytes[c] })
        .collect();
    assert_eq!(plan.hop_wire_bytes, want_bytes, "hop wire bytes ({ctx})");
    assert_eq!(
        planner.chain_expected_time(chain, &plan.cuts).to_bits(),
        plan.expected_time_s.to_bits(),
        "plan must achieve its reported time ({ctx})"
    );
    assert_eq!(
        plan.stage_counts(t.n).iter().sum::<usize>(),
        t.n,
        "stage counts must partition the net ({ctx})"
    );
    plan
}

/// Validate the oracle's own tables against the crate's independent
/// 2-tier implementation: at K = 2 the fresh fold must be bit-identical
/// to `Estimator::expected_time` at every split.
fn assert_tables_match_estimator(
    t: &Tables,
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    encoding: WireEncoding,
    paper: bool,
    ctx: &str,
) {
    let mut est = Estimator::new(desc, profile, link).with_encoding(encoding);
    if paper {
        est = est.paper_mode();
    }
    let two = TierChain::two_tier(link);
    for s in 0..=t.n {
        assert_eq!(
            price(t, &two, &[s]).to_bits(),
            est.expected_time(s).to_bits(),
            "oracle tables vs estimator at split {s} ({ctx})"
        );
    }
}

/// The tentpole obligation: on seeded random instances — net, profile,
/// exit probabilities (0/1 corners included), wire encoding, epsilon,
/// K ∈ {2, 3, 4}, per-hop links from the degenerate grids, per-tier
/// compute scales including exact 0.0 free relays — `plan_chain` is
/// bit-identical to the brute-force argmin over every monotone vector.
#[test]
fn plan_chain_is_bit_identical_to_the_exhaustive_argmin() {
    property("plan_chain == brute force over cut vectors", 120, |g| {
        let n = g.usize_in(2, 8);
        let mut desc = synthetic::random_desc(g, n, 3);
        // Hit the p = 0 / p = 1 corners with real probability mass.
        for b in &mut desc.branches {
            b.exit_prob = match g.usize_in(0, 9) {
                0 => 0.0,
                1 => 1.0,
                _ => g.probability(),
            };
        }
        let profile = synthetic::random_profile(g, &desc, g.f64_in(1.0, 500.0));
        let paper = g.bool(0.5);
        let epsilon = *g.choose(&[1e-12, 1e-9, 1e-3]);
        let encoding = *g.choose(&WireEncoding::ALL);

        let mut planner = Planner::new(&desc, &profile, epsilon, paper);
        if encoding != WireEncoding::Raw {
            planner = planner.with_wire_encoding(encoding);
        }
        let t = tables(&desc, &profile, encoding, paper);

        let k_tiers = *g.choose(&[2usize, 3, 4]);
        let links: Vec<LinkModel> = (0..k_tiers - 1)
            .map(|_| LinkModel::new(*g.choose(&BANDWIDTHS_MBPS), *g.choose(&RTTS_S)))
            .collect();
        let compute_scale: Vec<f64> = (0..k_tiers - 1)
            .map(|_| match g.usize_in(0, 3) {
                0 => 0.0, // free pass-through relay
                1 => 1.0,
                _ => g.f64_in(0.05, 8.0),
            })
            .collect();
        let chain = TierChain {
            links,
            compute_scale,
        };

        let ctx = format!(
            "n={n} K={k_tiers} paper={paper} eps={epsilon} enc={encoding:?} \
             scales={:?}",
            chain.compute_scale
        );
        assert_tables_match_estimator(
            &t,
            &desc,
            &profile,
            chain.links[0],
            encoding,
            paper,
            &ctx,
        );
        assert_matches_oracle(&planner, &t, &chain, epsilon, &ctx);
    });
}

/// Fixed 6-stage net with one branch — the pinned instance shared with
/// the joint oracle — for the exhaustive no-randomness corner sweeps.
fn pinned_instance(p: f64) -> (BranchyNetDesc, DelayProfile) {
    let desc = BranchyNetDesc {
        stage_names: (1..=6).map(|i| format!("s{i}")).collect(),
        stage_out_bytes: vec![57_600, 18_816, 25_088, 3_456, 1_024, 8],
        input_bytes: 12_288,
        branches: vec![BranchDesc {
            after_stage: 1,
            exit_prob: p,
        }],
    };
    let profile = DelayProfile::from_cloud_times(
        vec![1e-3, 1.5e-3, 1.2e-3, 8e-4, 3e-4, 5e-5],
        2e-4,
        10.0,
    );
    (desc, profile)
}

/// The same obligation on a pinned K = 3 grid — no randomness, every
/// combination visited: the full degenerate hop-0 grid × degenerate
/// second hops (dead, infinite, starved-with-60s-RTT, fat-with-∞-RTT) ×
/// compute scales including a free relay × p ∈ {0, ½, 1} × both planner
/// modes. Failures here reproduce without a seed.
#[test]
fn three_tier_degenerate_corners_match_the_oracle_exhaustively() {
    let hop1s = [
        LinkModel::new(0.0, 0.0),
        LinkModel::new(1e5, 0.0),
        LinkModel::new(1.10, 60.0),
        LinkModel::new(18.80, f64::INFINITY),
    ];
    let scale_pairs = [[0.0, 1.0], [1.0, 1.0], [4.0, 0.5]];
    for p in [0.0, 0.5, 1.0] {
        let (desc, profile) = pinned_instance(p);
        for paper in [true, false] {
            let planner = Planner::new(&desc, &profile, EPS, paper);
            let t = tables(&desc, &profile, WireEncoding::Raw, paper);
            for &mbps in &BANDWIDTHS_MBPS {
                for &rtt in &RTTS_S {
                    let hop0 = LinkModel::new(mbps, rtt);
                    assert_tables_match_estimator(
                        &t,
                        &desc,
                        &profile,
                        hop0,
                        WireEncoding::Raw,
                        paper,
                        &format!("p={p} paper={paper} hop0={mbps}/{rtt}"),
                    );
                    for hop1 in hop1s {
                        for scales in scale_pairs {
                            let chain = TierChain {
                                links: vec![hop0, hop1],
                                compute_scale: scales.to_vec(),
                            };
                            let ctx = format!(
                                "p={p} paper={paper} hop0={mbps}/{rtt} \
                                 hop1={}/{} scales={scales:?}",
                                hop1.uplink_mbps, hop1.rtt_s
                            );
                            let plan = assert_matches_oracle(&planner, &t, &chain, EPS, &ctx);
                            if p == 1.0 && plan.cuts[0] > 1 {
                                // Survival dies at the branch (after
                                // stage 1): a winner cutting past it
                                // never transfers, so the epsilon rule
                                // forbids every dead mid-net cut — only
                                // the all-edge vector with the all-N
                                // tail tie remains.
                                assert_eq!(plan.cuts, vec![6, 6], "{ctx}");
                                assert!(plan.is_edge_only(6), "{ctx}");
                                assert_eq!(plan.hop_wire_bytes, vec![0, 0], "{ctx}");
                            }
                        }
                    }
                }
            }
        }
    }
}

/// K = 4 pinned corners: two middle tiers, degenerate hops on every
/// position, free relays in both middle slots.
#[test]
fn four_tier_pinned_corners_match_the_oracle() {
    let hops = [
        LinkModel::new(0.05, 0.005),
        LinkModel::new(1.10, 0.1),
        LinkModel::new(0.0, 60.0),
        LinkModel::new(1e5, 0.0),
    ];
    let scale_triples = [[0.0, 0.0, 1.0], [1.0, 1.0, 1.0], [8.0, 0.25, 1.0]];
    for p in [0.0, 0.5, 1.0] {
        let (desc, profile) = pinned_instance(p);
        for paper in [true, false] {
            let planner = Planner::new(&desc, &profile, EPS, paper);
            let t = tables(&desc, &profile, WireEncoding::Raw, paper);
            for hop0 in hops {
                for hop1 in hops {
                    for hop2 in hops {
                        for scales in scale_triples {
                            let chain = TierChain {
                                links: vec![hop0, hop1, hop2],
                                compute_scale: scales.to_vec(),
                            };
                            let ctx = format!(
                                "p={p} paper={paper} hops=[{}/{}, {}/{}, {}/{}] \
                                 scales={scales:?}",
                                hop0.uplink_mbps,
                                hop0.rtt_s,
                                hop1.uplink_mbps,
                                hop1.rtt_s,
                                hop2.uplink_mbps,
                                hop2.rtt_s
                            );
                            assert_matches_oracle(&planner, &t, &chain, EPS, &ctx);
                        }
                    }
                }
            }
        }
    }
}

/// A free middle tier on a fat hop can only help: the 3-tier optimum is
/// never worse than the 2-tier optimum over the same first hop (every
/// `[s, N]` vector prices exactly like the 2-tier split `s` on a
/// unit-scale tail), and the oracle agrees with the planner on both.
#[test]
fn free_middle_tier_never_loses_to_the_two_tier_plan() {
    let (desc, profile) = pinned_instance(0.3);
    let planner = Planner::new(&desc, &profile, EPS, false);
    let t = tables(&desc, &profile, WireEncoding::Raw, false);
    for &mbps in &BANDWIDTHS_MBPS {
        let hop0 = LinkModel::new(mbps, 0.005);
        let chain = TierChain {
            links: vec![hop0, LinkModel::new(1e5, 0.0)],
            compute_scale: vec![0.0, 1.0],
        };
        let ctx = format!("mbps={mbps}");
        let three = assert_matches_oracle(&planner, &t, &chain, EPS, &ctx);
        let two = planner.plan_for(hop0);
        assert!(
            three.expected_time_s <= two.expected_time_s,
            "3-tier {} must not lose to 2-tier {} ({ctx})",
            three.expected_time_s,
            two.expected_time_s
        );
    }
}
