//! The planner refactor's core obligations: the precomputed, cached,
//! incremental `Planner` must agree with the paper-faithful oracle
//! (`solver::solve_faithful`, the literal `G'_BDNN` + Dijkstra of §V)
//! on randomized BranchyNets (0–3 branches, non-monotonic alphas from
//! the synthetic generator) across dense bandwidth sweeps — including
//! the cache-hit paths, whose plans must be byte-identical to an
//! uncached solve at the bucket representative — and the two-layer
//! core's p-views (`with_exit_probs` / `set_exit_probs`) must be
//! bit-identical to full constructions at the same p.

use std::time::Duration;

use branchyserve::model::synthetic;
use branchyserve::network::bandwidth::LinkModel;
use branchyserve::network::encoding::WireEncoding;
use branchyserve::partition::solver;
use branchyserve::planner::{AdaptiveConfig, JointSearchSpace, Planner, ReplanState, TierChain};
use branchyserve::testing::{property, Gen};

const EPS: f64 = 1e-9;

/// The acceptance property of the p-parameterized core: a view derived
/// by `with_exit_probs(p)` — one O(N·m) pass, no desc clone, no
/// re-validation, no graph work — must report `expected_time` bits
/// identical to a fresh, fully validated `Planner::new` at the same p,
/// for every split, across randomized networks and links. The same must
/// hold through a chain of in-place `set_exit_probs` swaps.
#[test]
fn exit_prob_views_are_bit_identical_to_full_construction() {
    property("with_exit_probs == Planner::new at p", 250, |g| {
        let n = g.usize_in(1, 40);
        let mut desc = synthetic::random_desc(g, n, 5);
        let profile = synthetic::random_profile(g, &desc, g.f64_in(1.0, 2000.0));
        let paper = g.bool(0.5);
        let base = Planner::new(&desc, &profile, EPS, paper);

        // Random target probabilities, including the 0/1 extremes.
        let probs: Vec<f64> = (0..desc.branches.len())
            .map(|_| match g.usize_in(0, 9) {
                0 => 0.0,
                1 => 1.0,
                _ => g.probability(),
            })
            .collect();

        // The cheap path vs the oracle: a fresh full construction from
        // a desc rewritten at the same probabilities.
        let rebuilt = base.with_exit_probs(&probs);
        desc.branches.sort_by_key(|b| b.after_stage);
        for (b, &p) in desc.branches.iter_mut().zip(&probs) {
            b.exit_prob = p;
        }
        let fresh = Planner::new(&desc, &profile, EPS, paper);

        // And the in-place swap path must land on the same view.
        let swapped = base.fork();
        swapped.set_exit_probs(&probs);

        for _ in 0..6 {
            let link = LinkModel::new(g.f64_in(0.01, 50_000.0), g.f64_in(0.0, 0.1));
            for s in 0..=n {
                let want = fresh.expected_time(s, link).to_bits();
                assert_eq!(
                    rebuilt.expected_time(s, link).to_bits(),
                    want,
                    "with_exit_probs split {s} (n={n}, paper={paper}, probs={probs:?})"
                );
                assert_eq!(
                    swapped.expected_time(s, link).to_bits(),
                    want,
                    "set_exit_probs split {s} (n={n}, paper={paper}, probs={probs:?})"
                );
            }
            let want_plan = fresh.plan_for(link);
            assert_eq!(rebuilt.plan_for(link), want_plan);
            assert_eq!(swapped.plan_for(link), want_plan);
            assert_eq!(
                rebuilt.plan_for(link).expected_time_s.to_bits(),
                want_plan.expected_time_s.to_bits()
            );
        }
    });
}

/// The joint search's degeneration obligation: restricted to the
/// planner's current branch set (live-view probabilities) under its
/// baked wire encoding, `plan_joint` must collapse to the paper's
/// one-axis optimizer — `plan_for`'s split and expected time, bit for
/// bit — across randomized nets, p-updates, encoding re-bakes, and
/// links.
#[test]
fn restricted_joint_space_degenerates_to_plan_for() {
    property("plan_joint(restricted) == plan_for", 200, |g| {
        let n = g.usize_in(1, 30);
        let desc = synthetic::random_desc(g, n, 4);
        let profile = synthetic::random_profile(g, &desc, g.f64_in(1.0, 2000.0));
        let paper = g.bool(0.5);
        let mut planner = Planner::new(&desc, &profile, EPS, paper);

        // Exercise the restricted space against a mutated planner, not
        // just the constructed one: random encoding re-bake and random
        // in-place p-swap.
        let encoding = *g.choose(&WireEncoding::ALL);
        if encoding != WireEncoding::Raw {
            planner = planner.with_wire_encoding(encoding);
        }
        if g.bool(0.5) && !desc.branches.is_empty() {
            let probs: Vec<f64> = (0..desc.branches.len()).map(|_| g.probability()).collect();
            planner.set_exit_probs(&probs);
        }

        let space = JointSearchSpace::restricted(&planner);
        assert_eq!(space.encodings, vec![planner.wire_encoding()]);
        for _ in 0..4 {
            let link = LinkModel::new(g.f64_in(0.01, 50_000.0), g.f64_in(0.0, 0.1));
            let fixed = planner.plan_for(link);
            let joint = planner.plan_joint(link, &space);
            assert_eq!(
                joint.split, fixed.split_after,
                "n={n} paper={paper} enc={encoding:?}"
            );
            assert_eq!(
                joint.expected_time.to_bits(),
                fixed.expected_time_s.to_bits(),
                "n={n} paper={paper} enc={encoding:?}"
            );
            assert_eq!(joint.ranked.len(), 1);
            assert_eq!(joint.pruned, 0);
        }
    });
}

/// The chain generalization's degeneration obligation: `plan_chain`
/// over [`TierChain::two_tier`] must collapse to the paper's one-axis
/// optimizer — `plan_for`'s cut, expected-time bits and wire bytes —
/// across randomized nets, encoding re-bakes, p-updates and links, and
/// the explicit chain pricing must agree with the 2-tier sweep kernel
/// bit-for-bit at every cut.
#[test]
fn two_tier_chain_degenerates_to_plan_for() {
    property("plan_chain(two_tier) == plan_for", 200, |g| {
        let n = g.usize_in(1, 30);
        let desc = synthetic::random_desc(g, n, 4);
        let profile = synthetic::random_profile(g, &desc, g.f64_in(1.0, 2000.0));
        let paper = g.bool(0.5);
        let mut planner = Planner::new(&desc, &profile, EPS, paper);

        let encoding = *g.choose(&WireEncoding::ALL);
        if encoding != WireEncoding::Raw {
            planner = planner.with_wire_encoding(encoding);
        }
        if g.bool(0.5) && !desc.branches.is_empty() {
            let probs: Vec<f64> = (0..desc.branches.len()).map(|_| g.probability()).collect();
            planner.set_exit_probs(&probs);
        }

        for _ in 0..6 {
            let link = LinkModel::new(g.f64_in(0.01, 50_000.0), g.f64_in(0.0, 0.1));
            let two = TierChain::two_tier(link);
            let fixed = planner.plan_for(link);
            let chain = planner.plan_chain(&two);
            assert_eq!(
                chain.cuts,
                vec![fixed.split_after],
                "n={n} paper={paper} enc={encoding:?}"
            );
            assert_eq!(
                chain.expected_time_s.to_bits(),
                fixed.expected_time_s.to_bits(),
                "n={n} paper={paper} enc={encoding:?}"
            );
            assert_eq!(chain.hop_wire_bytes, vec![fixed.wire_bytes]);
            assert_eq!(chain.is_edge_only(n), fixed.is_edge_only(n));
            for s in 0..=n {
                assert_eq!(
                    planner.chain_expected_time(&two, &[s]).to_bits(),
                    planner.expected_time(s, link).to_bits(),
                    "chain pricing vs sweep kernel at cut {s} (n={n}, paper={paper})"
                );
            }
        }
    });
}

#[test]
fn planner_matches_faithful_solver_on_random_instances() {
    property("planner == solve_faithful", 200, |g| {
        let n = g.usize_in(1, 24);
        let desc = synthetic::random_desc(g, n, 3); // 0..=3 branches
        let gamma = g.f64_in(1.0, 2000.0);
        let profile = synthetic::random_profile(g, &desc, gamma);
        let paper = g.bool(0.5);
        let planner = Planner::new(&desc, &profile, EPS, paper);

        for _ in 0..8 {
            let link = LinkModel::new(g.f64_in(0.05, 100.0), g.f64_in(0.0, 0.02));
            let ours = planner.plan_for(link);
            let oracle = solver::solve_faithful(&desc, &profile, link, EPS, paper);

            // Optimal expected times agree up to the epsilon tie-breaker
            // plus fp noise between the two summation orders.
            let tol = EPS + 1e-9 * oracle.expected_time_s.abs().max(1.0);
            assert!(
                (ours.expected_time_s - oracle.expected_time_s).abs() <= tol,
                "planner {} vs faithful {} (n={n}, gamma={gamma:.1}, paper={paper})",
                ours.expected_time_s,
                oracle.expected_time_s
            );
            // Whenever the two resolve to the same split — everywhere
            // except fp-exact ties, where the tie direction is the
            // solver's to choose — the plans must be byte-identical:
            // same expected time bits, same active branches, same
            // transfer bytes, same strategy.
            if ours.split_after == oracle.split_after {
                assert_eq!(ours, oracle, "same split must mean identical plans");
                assert_eq!(
                    ours.expected_time_s.to_bits(),
                    oracle.expected_time_s.to_bits()
                );
            }
        }
    });
}

/// Fixed corpus instance with deliberately non-monotonic alphas (the
/// B-AlexNet shape: outputs grow again at conv3) for the dense sweep.
fn sweep_instance(
    branches: usize,
) -> (
    branchyserve::model::BranchyNetDesc,
    branchyserve::timing::DelayProfile,
) {
    use branchyserve::model::{BranchDesc, BranchyNetDesc};
    use branchyserve::timing::DelayProfile;
    let all = [(1usize, 0.5f64), (3, 0.3), (5, 0.8)];
    let desc = BranchyNetDesc {
        stage_names: (1..=8).map(|i| format!("s{i}")).collect(),
        stage_out_bytes: vec![57_600, 18_816, 25_088, 25_088, 3_456, 1_024, 512, 8],
        input_bytes: 12_288,
        branches: all[..branches]
            .iter()
            .map(|&(after_stage, exit_prob)| BranchDesc {
                after_stage,
                exit_prob,
            })
            .collect(),
    };
    let profile = DelayProfile::from_cloud_times(
        vec![8.4e-4, 1.2e-3, 3.3e-4, 4.5e-4, 3.6e-4, 5.2e-5, 4.0e-5, 4.7e-5],
        4.0e-4,
        50.0,
    );
    (desc, profile)
}

#[test]
fn thousand_point_bandwidth_sweep_including_cache_hits() {
    for branches in [0usize, 1, 3] {
        let (desc, profile) = sweep_instance(branches);
        let planner = Planner::new(&desc, &profile, EPS, true);

        // 1000 points, log-spaced over 0.05..500 Mbps (4 decades).
        let links: Vec<LinkModel> = (0..1000)
            .map(|i| LinkModel::new(0.05 * 10f64.powf(4.0 * i as f64 / 999.0), 0.0))
            .collect();

        for &link in &links {
            // Exact path vs the faithful oracle.
            let exact = planner.plan_for(link);
            let oracle = solver::solve_faithful(&desc, &profile, link, EPS, true);
            let tol = EPS + 1e-9 * oracle.expected_time_s.abs().max(1.0);
            assert!(
                (exact.expected_time_s - oracle.expected_time_s).abs() <= tol,
                "branches={branches} @ {:.3} Mbps: planner {} vs faithful {}",
                link.uplink_mbps,
                exact.expected_time_s,
                oracle.expected_time_s
            );
            if exact.split_after == oracle.split_after {
                assert_eq!(exact, oracle);
            }

            // Cached path: byte-identical to an uncached solve at the
            // bucket representative...
            let cached = planner.plan_cached(link);
            let rep = planner.cache_representative(link);
            assert_eq!(cached, planner.plan_for(rep));
            // ...and near-optimal at the true link: bounded by the
            // bucket's relative width (~10%), squared through the
            // cost ratio, so 15% is a safe envelope.
            let cached_cost_here = planner.expected_time(cached.split_after, link);
            assert!(
                cached_cost_here <= exact.expected_time_s * 1.15 + EPS,
                "branches={branches} @ {:.3} Mbps: cached split {} costs {} vs optimal {}",
                link.uplink_mbps,
                cached.split_after,
                cached_cost_here,
                exact.expected_time_s
            );
        }

        // The sweep crosses ~4 decades at ~24 buckets/decade: the cache
        // must have absorbed the bulk of the 1000 queries.
        let (hits, misses) = planner.cache_stats();
        assert_eq!(hits + misses, 1000, "every query goes through the cache");
        assert!(
            (50..=150).contains(&(misses as usize)),
            "expected ~97 distinct buckets over 4 decades, got {misses}"
        );

        // A second identical sweep must be 100% hits.
        for &link in &links {
            let _ = planner.plan_cached(link);
        }
        let (hits2, misses2) = planner.cache_stats();
        assert_eq!(misses2, misses, "revisit must not miss");
        assert_eq!(hits2, hits + 1000);
    }
}

#[test]
fn replan_state_tracks_a_trace_without_flapping() {
    // Drive the pure replan state machine through a Wi-Fi -> 3G -> 4G
    // trace with ±2% jitter: it must settle on one split per phase
    // (hysteresis), not oscillate within a phase. gamma = 20 puts the
    // 3G phase in the edge-only regime and the 4G/Wi-Fi phases in the
    // cloud-only regime, so the trace genuinely moves the split.
    let (desc, profile) = sweep_instance(1);
    let profile = profile.with_gamma(20.0);
    let planner = Planner::new(&desc, &profile, EPS, false);
    let mut state = ReplanState::new(
        planner,
        AdaptiveConfig {
            interval: Duration::from_millis(1),
            min_improvement: 0.02,
            min_dwell: Duration::ZERO,
        },
    );

    let mut g = Gen::replay(0x77ACE);
    let mut switches_per_phase = Vec::new();
    let mut now = 0.0f64;
    for &phase_mbps in &[18.80f64, 1.10, 5.85] {
        let mut switches = 0u64;
        for _ in 0..200 {
            let jitter = 1.0 + g.f64_in(-0.02, 0.02);
            if state
                .observe(LinkModel::new(phase_mbps * jitter, 0.0), now)
                .is_some()
            {
                switches += 1;
            }
            now += 0.5;
        }
        switches_per_phase.push(switches);
    }
    // At most one adoption per phase; jitter never flaps the plan.
    assert!(
        switches_per_phase.iter().all(|&s| s <= 1),
        "{switches_per_phase:?}"
    );
    // And the bandwidth collapse from Wi-Fi to 3G must have moved it.
    let stats = state.stats();
    assert!(stats.switches >= 2, "{stats:?}");
    assert_eq!(stats.replans, 600);
    assert!(
        stats.cache_hits > 500,
        "per-phase jitter should be cache hits: {stats:?}"
    );
}
