//! Numerics round-trip over the real artifacts: the Rust PJRT runtime
//! must reproduce the Python-computed fixtures bit-for-bit (same XLA CPU
//! backend, same HLO) — stage by stage, branch head, monolith, and across
//! kernel flavors. Requires `make artifacts`.

use std::path::Path;

use branchyserve::config::settings::Flavor;
use branchyserve::model::Manifest;
use branchyserve::runtime::{fixture, HostTensor, InferenceEngine};

fn setup(flavor: Flavor) -> Option<(Manifest, InferenceEngine)> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    let manifest = Manifest::load(dir).expect("manifest loads");
    let engine =
        InferenceEngine::open(dir, manifest.clone(), flavor, "roundtrip").expect("engine");
    Some((manifest, engine))
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let mut max_diff = 0f32;
    for (a, b) in got.iter().zip(want) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff <= tol, "{what}: max diff {max_diff} > {tol}");
}

#[test]
fn ref_flavor_stagewise_matches_python_fixtures() {
    let Some((manifest, engine)) = setup(Flavor::Ref) else {
        return;
    };
    let input = fixture::load(&manifest.fixture("input_b8").unwrap()).unwrap();
    let mut x = input;
    for i in 1..=manifest.num_stages() {
        x = engine.run_stages(i, i, &x).unwrap();
        let expected = fixture::load(
            &manifest
                .fixture(&format!("expected_stage{i:02}_b8"))
                .unwrap(),
        )
        .unwrap();
        assert_eq!(x.shape(), expected.shape(), "stage {i} shape");
        // Same backend + same HLO -> exact equality is expected; allow a
        // hair of slack for run-to-run nondeterminism in reductions.
        assert_close(x.data(), expected.data(), 1e-5, &format!("stage {i}"));
    }
}

#[test]
fn branch_head_matches_python_probs_and_entropy() {
    let Some((manifest, engine)) = setup(Flavor::Ref) else {
        return;
    };
    let input = fixture::load(&manifest.fixture("input_b8").unwrap()).unwrap();
    let acts = engine
        .run_stages(1, manifest.branch.after_stage, &input)
        .unwrap();
    let out = engine.run_branch(&acts).unwrap();
    let probs = fixture::load(&manifest.fixture("expected_branch_probs_b8").unwrap()).unwrap();
    let entropy =
        fixture::load(&manifest.fixture("expected_branch_entropy_b8").unwrap()).unwrap();
    assert_close(out.probs.data(), probs.data(), 1e-5, "branch probs");
    assert_close(&out.entropy, entropy.data(), 1e-5, "branch entropy");
    // Entropy within [0, ln C].
    let max_nats = manifest.entropy_max_nats as f32;
    for &e in &out.entropy {
        assert!((0.0..=max_nats + 1e-5).contains(&e), "entropy {e}");
    }
}

#[test]
fn composed_stages_equal_monolithic_full_model() {
    let Some((manifest, engine)) = setup(Flavor::Ref) else {
        return;
    };
    let input = fixture::load(&manifest.fixture("input_b8").unwrap()).unwrap();
    let composed = engine.run_stages(1, manifest.num_stages(), &input).unwrap();
    let full = engine.run_full(&input).unwrap();
    assert_eq!(composed.shape(), full.shape());
    assert_close(composed.data(), full.data(), 1e-4, "composed vs monolith");
}

#[test]
fn pallas_flavor_matches_ref_flavor() {
    let Some((manifest, engine_ref)) = setup(Flavor::Ref) else {
        return;
    };
    let Some((_, engine_pl)) = setup(Flavor::Pallas) else {
        return;
    };
    let input = fixture::load(&manifest.fixture("input_b8").unwrap()).unwrap();
    let a = engine_ref
        .run_stages(1, manifest.num_stages(), &input)
        .unwrap();
    let b = engine_pl
        .run_stages(1, manifest.num_stages(), &input)
        .unwrap();
    // Different contraction orders (blocked pallas vs fused XLA) -> small
    // fp drift through 8 stages.
    assert_close(a.data(), b.data(), 2e-2, "pl vs ref logits");
    // Predicted classes must agree.
    assert_eq!(
        InferenceEngine::argmax_classes(&a),
        InferenceEngine::argmax_classes(&b)
    );
}

#[test]
fn every_exported_batch_size_executes() {
    let Some((manifest, engine)) = setup(Flavor::Ref) else {
        return;
    };
    for &b in &manifest.batch_sizes {
        let mut shape = vec![b];
        shape.extend(&manifest.input_shape);
        let x = HostTensor::zeros(shape);
        let out = engine.run_stages(1, 1, &x).unwrap();
        assert_eq!(out.batch(), b);
    }
    // Unexported batch size must be rejected, not miscomputed.
    let mut shape = vec![3];
    shape.extend(&manifest.input_shape);
    assert!(engine.run_stages(1, 1, &HostTensor::zeros(shape)).is_err());
}

#[test]
fn trained_model_classifies_fixture_labels() {
    let Some((manifest, engine)) = setup(Flavor::Ref) else {
        return;
    };
    let input = fixture::load(&manifest.fixture("input_b8").unwrap()).unwrap();
    let logits = engine.run_stages(1, manifest.num_stages(), &input).unwrap();
    let classes = InferenceEngine::argmax_classes(&logits);
    let labels_path = Path::new("artifacts/fixtures/labels_b8.json");
    let labels: Vec<usize> = branchyserve::config::json::Json::parse(
        &std::fs::read_to_string(labels_path).unwrap(),
    )
    .unwrap()
    .as_usize_vec()
    .unwrap();
    let correct = classes
        .iter()
        .zip(&labels)
        .filter(|(a, b)| a == b)
        .count();
    assert!(
        correct >= 7,
        "trained model got {correct}/8 on its own fixtures ({classes:?} vs {labels:?})"
    );
}

#[test]
fn invalid_stage_ranges_rejected() {
    let Some((manifest, engine)) = setup(Flavor::Ref) else {
        return;
    };
    let mut shape = vec![1];
    shape.extend(&manifest.input_shape);
    let x = HostTensor::zeros(shape);
    assert!(engine.run_stages(0, 1, &x).is_err());
    assert!(engine.run_stages(2, 1, &x).is_err());
    assert!(engine
        .run_stages(1, manifest.num_stages() + 1, &x)
        .is_err());
}

#[test]
fn missing_artifacts_dir_gives_actionable_error() {
    let err = Manifest::load(Path::new("/nonexistent/artifacts")).unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
}

#[cfg(feature = "xla-pjrt")]
#[test]
fn corrupt_hlo_artifact_fails_cleanly() {
    // A store pointed at a dir with a garbage .hlo.txt must error on
    // compile, not crash, and must keep serving other artifacts.
    let Some((manifest, _)) = setup(Flavor::Ref) else {
        return;
    };
    let dir = std::env::temp_dir().join("branchyserve_corrupt_test");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule utterly { garbage").unwrap();
    // Copy one good artifact alongside.
    let good = manifest.stages[0].artifact(Flavor::Ref, 1).unwrap();
    std::fs::copy(Path::new("artifacts").join(good), dir.join(good)).unwrap();

    let store = branchyserve::runtime::ArtifactStore::open(&dir).unwrap();
    assert!(store.get("bad.hlo.txt").is_err());
    assert!(store.get("missing.hlo.txt").is_err());
    assert!(store.get(good).is_ok());
    assert_eq!(store.cached_count(), 1);
}

#[test]
fn profiler_measures_on_real_artifacts() {
    let Some((_, engine)) = setup(Flavor::Ref) else {
        return;
    };
    let opts = branchyserve::profiler::ProfileOptions {
        warmup: 1,
        iters: 3,
        trim: 0.0,
        batch: 1,
    };
    let report = branchyserve::profiler::measure(&engine, opts).unwrap();
    assert_eq!(report.stages.len(), engine.manifest().num_stages());
    for s in &report.stages {
        assert!(s.t_cloud_s > 0.0 && s.min_s <= s.t_cloud_s);
    }
    assert!(report.branch.t_cloud_s > 0.0);
    // Save/load round-trip through the JSON substrate.
    let path = std::env::temp_dir().join("branchyserve_profile_test.json");
    report.save(&path).unwrap();
    let loaded = branchyserve::profiler::ProfileReport::load(&path).unwrap();
    assert_eq!(loaded.stages.len(), report.stages.len());
    assert!((loaded.stages[0].t_cloud_s - report.stages[0].t_cloud_s).abs() < 1e-12);
    std::fs::remove_file(&path).ok();
}
