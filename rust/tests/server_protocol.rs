//! TCP front-end integration: ping/infer/metrics over a live socket,
//! concurrent clients, malformed input handling. Requires `make artifacts`.

use std::io::Write;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use branchyserve::config::settings::{Flavor, Strategy};
use branchyserve::coordinator::{Coordinator, CoordinatorConfig};
use branchyserve::model::Manifest;
use branchyserve::network::{BandwidthTrace, Channel};
use branchyserve::partition::PartitionPlan;
use branchyserve::runtime::{HostTensor, InferenceEngine};
use branchyserve::server::tcp::Client;
use branchyserve::server::{Request, Response, Server};
use branchyserve::workload::ImageSource;

fn start_server() -> Option<(branchyserve::server::ServerHandle, std::net::SocketAddr)> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    let manifest = Manifest::load(dir).unwrap();
    let edge = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "srv-edge").unwrap();
    let cloud = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "srv-cloud").unwrap();
    let plan = PartitionPlan::from_split(2, 0.0, Strategy::ShortestPath, &manifest.to_desc(0.5));
    let coordinator = Arc::new(Coordinator::start(
        edge,
        cloud,
        Arc::new(Channel::new(BandwidthTrace::constant(1000.0), 0.0, 0.0, 0).simulated_time()),
        plan,
        CoordinatorConfig {
            entropy_threshold: 0.4,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        },
    ));
    let handle = Server::new(coordinator).start(0).unwrap();
    let addr = handle.addr();
    Some((handle, addr))
}

#[test]
fn ping_infer_metrics_roundtrip() {
    let Some((handle, addr)) = start_server() else {
        return;
    };
    let mut client = Client::connect(addr).unwrap();
    client.ping().unwrap();

    let mut source = ImageSource::new(77);
    for _ in 0..4 {
        let (img, _) = source.sample();
        match client.infer(img).unwrap() {
            Response::Result {
                class, latency_s, ..
            } => {
                assert!(class < 2);
                assert!(latency_s > 0.0);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    match client.call(&Request::Metrics).unwrap() {
        Response::Metrics(json) => {
            let v = branchyserve::config::json::Json::parse(&json).unwrap();
            assert_eq!(v.get("completed").unwrap().as_u64(), Some(4));
        }
        other => panic!("unexpected {other:?}"),
    }
    handle.stop();
}

#[test]
fn concurrent_clients() {
    let Some((handle, addr)) = start_server() else {
        return;
    };
    let mut joins = Vec::new();
    for c in 0..6 {
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            let mut source = ImageSource::new(500 + c);
            let mut ok = 0;
            for _ in 0..5 {
                let (img, _) = source.sample();
                if matches!(client.infer(img).unwrap(), Response::Result { .. }) {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, 30);
    handle.stop();
}

#[test]
fn wrong_shape_infer_returns_error_frame() {
    let Some((handle, addr)) = start_server() else {
        return;
    };
    let mut client = Client::connect(addr).unwrap();
    // 2x2 image: HostTensor is valid, but the engine rejects the shape.
    let bogus = HostTensor::new(vec![2, 2], vec![0.0; 4]).unwrap();
    match client.infer(bogus).unwrap() {
        Response::Error(msg) => assert!(!msg.is_empty()),
        other => panic!("expected error, got {other:?}"),
    }
    // Connection still usable afterwards.
    client.ping().unwrap();
    handle.stop();
}

#[test]
fn garbage_bytes_close_connection_not_server() {
    let Some((handle, addr)) = start_server() else {
        return;
    };
    {
        let mut raw = std::net::TcpStream::connect(addr).unwrap();
        raw.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        // Server drops this connection; no panic.
    }
    // Server still serves fresh clients.
    let mut client = Client::connect(addr).unwrap();
    client.ping().unwrap();
    handle.stop();
}
