//! TCP front-end integration. The first half drives a real-artifact
//! coordinator (ping/infer/metrics over a live socket, concurrent
//! clients, malformed input) and requires `make artifacts`. The second
//! half runs entirely on the simulated runtime — shutdown hygiene,
//! `max_conns` shedding, THROTTLE backpressure, and the
//! reactor-vs-thread-per-connection bit-identity proof need no
//! artifacts.

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use branchyserve::config::settings::{Flavor, Strategy};
use branchyserve::coordinator::{Coordinator, CoordinatorConfig};
use branchyserve::fleet::{ClassProfile, ClassRegistry, Fleet, FleetConfig};
use branchyserve::model::Manifest;
use branchyserve::network::{BandwidthTrace, Channel};
use branchyserve::partition::PartitionPlan;
use branchyserve::runtime::{HostTensor, InferenceEngine};
use branchyserve::server::protocol::{read_frame, write_frame};
use branchyserve::server::tcp::Client;
use branchyserve::server::{
    Request, Response, Server, ServerConfig, THROTTLE_RETRY_AFTER_MS,
};
use branchyserve::timing::DelayProfile;
use branchyserve::workload::ImageSource;

fn start_server() -> Option<(branchyserve::server::ServerHandle, std::net::SocketAddr)> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    let manifest = Manifest::load(dir).unwrap();
    let edge = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "srv-edge").unwrap();
    let cloud = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "srv-cloud").unwrap();
    let plan = PartitionPlan::from_split(2, 0.0, Strategy::ShortestPath, &manifest.to_desc(0.5));
    let coordinator = Arc::new(Coordinator::start(
        edge,
        cloud,
        Arc::new(Channel::new(BandwidthTrace::constant(1000.0), 0.0, 0.0, 0).simulated_time()),
        plan,
        CoordinatorConfig {
            entropy_threshold: 0.4,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        },
    ));
    let handle = Server::new(coordinator).start(0).unwrap();
    let addr = handle.addr();
    Some((handle, addr))
}

#[test]
fn ping_infer_metrics_roundtrip() {
    let Some((handle, addr)) = start_server() else {
        return;
    };
    let mut client = Client::connect(addr).unwrap();
    client.ping().unwrap();

    let mut source = ImageSource::new(77);
    for _ in 0..4 {
        let (img, _) = source.sample();
        match client.infer(img).unwrap() {
            Response::Result {
                class, latency_s, ..
            } => {
                assert!(class < 2);
                assert!(latency_s > 0.0);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    match client.call(&Request::Metrics).unwrap() {
        Response::Metrics(json) => {
            let v = branchyserve::config::json::Json::parse(&json).unwrap();
            assert_eq!(v.get("completed").unwrap().as_u64(), Some(4));
        }
        other => panic!("unexpected {other:?}"),
    }
    handle.stop();
}

#[test]
fn concurrent_clients() {
    let Some((handle, addr)) = start_server() else {
        return;
    };
    let mut joins = Vec::new();
    for c in 0..6 {
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            let mut source = ImageSource::new(500 + c);
            let mut ok = 0;
            for _ in 0..5 {
                let (img, _) = source.sample();
                if matches!(client.infer(img).unwrap(), Response::Result { .. }) {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, 30);
    handle.stop();
}

#[test]
fn wrong_shape_infer_returns_error_frame() {
    let Some((handle, addr)) = start_server() else {
        return;
    };
    let mut client = Client::connect(addr).unwrap();
    // 2x2 image: HostTensor is valid, but the engine rejects the shape.
    let bogus = HostTensor::new(vec![2, 2], vec![0.0; 4]).unwrap();
    match client.infer(bogus).unwrap() {
        Response::Error(msg) => assert!(!msg.is_empty()),
        other => panic!("expected error, got {other:?}"),
    }
    // Connection still usable afterwards.
    client.ping().unwrap();
    handle.stop();
}

#[test]
fn garbage_bytes_close_connection_not_server() {
    let Some((handle, addr)) = start_server() else {
        return;
    };
    {
        let mut raw = std::net::TcpStream::connect(addr).unwrap();
        raw.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        // Server drops this connection; no panic.
    }
    // Server still serves fresh clients.
    let mut client = Client::connect(addr).unwrap();
    client.ping().unwrap();
    handle.stop();
}

// ---------------------------------------------------------------------
// Simulated-runtime front-end tests (no artifacts required).
// ---------------------------------------------------------------------

const SIM_STAGES: usize = 3;

fn front_manifest() -> Manifest {
    Manifest::synthetic_sim("sim-front", vec![4], &[16, 8, 2], 1, 2, vec![1, 2, 4, 8]).unwrap()
}

fn front_profile() -> DelayProfile {
    DelayProfile::from_cloud_times(vec![1e-4; SIM_STAGES], 2e-5, 50.0)
}

/// Two-class sim fleet ("slow" plans edge-only, "fast" cloud-only) with
/// a controllable synthetic stage cost.
fn sim_fleet(stage_cost: Duration) -> Fleet {
    let manifest = front_manifest();
    let m = manifest.clone();
    Fleet::start(
        ClassRegistry::new(vec![
            ClassProfile::custom("slow", 0.05, 0.0).unwrap(),
            ClassProfile::custom("fast", 100_000.0, 0.0).unwrap(),
        ])
        .unwrap(),
        &manifest,
        &front_profile(),
        FleetConfig {
            batch_timeout: Duration::from_millis(1),
            real_time_channel: false,
            entropy_threshold: 0.0, // deterministic: nothing exits early
            ..Default::default()
        },
        move |label| {
            Ok((
                InferenceEngine::open_sim_with_cost(m.clone(), &format!("{label}-e"), stage_cost)?,
                InferenceEngine::open_sim_with_cost(m.clone(), &format!("{label}-c"), stage_cost)?,
            ))
        },
    )
    .unwrap()
}

fn inputs(n: usize) -> Vec<HostTensor> {
    (0..n)
        .map(|i| {
            let base = i as f32 * 0.41 - 1.2;
            HostTensor::new(vec![4], vec![base, base * -0.7, 0.3 + base, 1.1 - base]).unwrap()
        })
        .collect()
}

/// Drive one connection lockstep (write a frame, read its answer) and
/// return the raw response bodies.
fn exchange(addr: SocketAddr, reqs: &[Request]) -> Vec<Vec<u8>> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = Vec::with_capacity(reqs.len());
    for r in reqs {
        write_frame(&mut stream, &r.encode()).unwrap();
        out.push(read_frame(&mut reader).unwrap());
    }
    out
}

/// Re-encode a response body with its wall-clock fields zeroed, so two
/// serving paths can be compared bit-for-bit on everything that is
/// deterministic (ids, classes, entropies, flags, error text, layout).
fn normalized(body: &[u8]) -> Vec<u8> {
    let mut resp = Response::decode(body).unwrap();
    match &mut resp {
        Response::Result { latency_s, .. } => *latency_s = 0.0,
        Response::PartialResult { cloud_s, .. } => *cloud_s = 0.0,
        Response::PartialResultSeq { cloud_s, .. } => *cloud_s = 0.0,
        _ => {}
    }
    resp.encode()
}

/// Satellite regression: `stop()` must return promptly even with idle
/// connections still open — handler threads are tracked, their sockets
/// shut down, and every one joined (no detached-thread leak, no hang on
/// a blocked `read_frame`).
#[test]
fn stop_returns_promptly_with_idle_connections_open() {
    let fleet = Arc::new(sim_fleet(Duration::ZERO));
    let handle = Server::new(fleet.clone()).start(0).unwrap();
    let mut idle = Vec::new();
    for _ in 0..3 {
        let mut c = Client::connect(handle.addr()).unwrap();
        c.ping().unwrap(); // handler thread confirmed live
        idle.push(c); // ...and then left idle, blocking in read_frame
    }
    let t0 = Instant::now();
    handle.stop();
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(5),
        "stop() hung on idle connections: {elapsed:?}"
    );
}

/// `max_conns` on the thread-per-connection path: the connection over
/// the cap gets one THROTTLE frame and a close, the counter records it,
/// and the fleet's metrics JSON carries the front-end counters.
#[test]
fn max_conns_shed_answers_throttle_and_counts() {
    let fleet = Arc::new(sim_fleet(Duration::ZERO));
    let handle = Server::with_config(
        fleet.clone(),
        ServerConfig {
            max_conns: 2,
            ..ServerConfig::default()
        },
    )
    .start(0)
    .unwrap();

    let mut c1 = Client::connect(handle.addr()).unwrap();
    c1.ping().unwrap();
    let mut c2 = Client::connect(handle.addr()).unwrap();
    c2.ping().unwrap();

    // Third connection: shed with THROTTLE, then EOF.
    let shed = TcpStream::connect(handle.addr()).unwrap();
    let mut shed_reader = BufReader::new(shed);
    let resp = Response::decode(&read_frame(&mut shed_reader).unwrap()).unwrap();
    assert_eq!(
        resp,
        Response::Throttle {
            retry_after_ms: THROTTLE_RETRY_AFTER_MS
        }
    );
    assert!(
        read_frame(&mut shed_reader).is_err(),
        "shed connection must be closed after the THROTTLE frame"
    );

    let snap = handle.stats().snapshot();
    assert_eq!(snap.conns_shed, 1);
    assert_eq!(snap.accepted, 2);
    assert_eq!(snap.active, 2);
    assert_eq!(snap.conn_peak, 2);

    // The backend registered the same counters: METRICS carries them.
    match c1.call(&Request::Metrics).unwrap() {
        Response::Metrics(json) => {
            assert!(json.contains("\"conns_shed\":1"), "{json}");
            assert!(json.contains("\"accepted\":2"), "{json}");
        }
        other => panic!("unexpected {other:?}"),
    }
    handle.stop();
}

/// THROTTLE survives the framed wire byte-exactly, and malformed bodies
/// are rejected instead of misparsed.
#[test]
fn throttle_frames_survive_the_wire_and_reject_garbage() {
    let mut buf = Vec::new();
    write_frame(
        &mut buf,
        &Response::Throttle {
            retry_after_ms: 1234,
        }
        .encode(),
    )
    .unwrap();
    let body = read_frame(&mut &buf[..]).unwrap();
    assert_eq!(
        Response::decode(&body).unwrap(),
        Response::Throttle {
            retry_after_ms: 1234
        }
    );
    // Truncated and trailing-garbage THROTTLE bodies fail loudly.
    assert!(Response::decode(&[5]).is_err());
    assert!(Response::decode(&[5, 1, 0]).is_err());
    assert!(Response::decode(&[5, 1, 0, 0, 0, 9]).is_err());
}

/// The tentpole's correctness proof, fleet half: the reactor answers
/// the exact same bytes as the thread-per-connection path for an
/// identical INFER / INFER_CLASS request stream (wall-clock latency
/// normalized out — everything else, ids and error text included, must
/// match bit-for-bit).
#[cfg(target_os = "linux")]
#[test]
fn reactor_responses_are_bit_identical_to_thread_per_conn() {
    let thread_fleet = Arc::new(sim_fleet(Duration::ZERO));
    let reactor_fleet = Arc::new(sim_fleet(Duration::ZERO));
    let thread_srv = Server::new(thread_fleet.clone()).start(0).unwrap();
    let reactor_srv = Server::with_config(
        reactor_fleet.clone(),
        ServerConfig {
            reactor: true,
            reactor_threads: 2,
            ..ServerConfig::default()
        },
    )
    .start(0)
    .unwrap();

    let mut stream = vec![Request::Ping];
    for (i, img) in inputs(6).into_iter().enumerate() {
        stream.push(match i % 3 {
            0 => Request::Infer(img),
            1 => Request::InferClass { class: 0, image: img },
            _ => Request::InferClass { class: 1, image: img },
        });
    }
    // An unknown class tag answers a deterministic ERROR frame — the
    // two paths must even fail identically.
    stream.push(Request::InferClass {
        class: 9,
        image: inputs(1).pop().unwrap(),
    });

    let a = exchange(thread_srv.addr(), &stream);
    let b = exchange(reactor_srv.addr(), &stream);
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(normalized(x), normalized(y), "frame {i} diverged");
    }

    thread_srv.stop();
    reactor_srv.stop();
}

/// Bit-identity, cloud-stage half: INFER_PARTIAL and INFER_PARTIAL_SEQ
/// (the kinds a remote edge ships) answer identically through both
/// front ends.
#[cfg(target_os = "linux")]
#[test]
fn reactor_partial_responses_match_thread_per_conn() {
    use branchyserve::network::WireEncoding;
    use branchyserve::server::protocol::{BRANCH_GATED, BRANCH_PENDING};
    use branchyserve::server::CloudStageServer;

    let thread_css = Arc::new(CloudStageServer::new(
        InferenceEngine::open_sim(front_manifest(), "bit-css-t").unwrap(),
    ));
    let reactor_css = Arc::new(CloudStageServer::new(
        InferenceEngine::open_sim(front_manifest(), "bit-css-r").unwrap(),
    ));
    let thread_srv = Server::new(thread_css).start(0).unwrap();
    let reactor_srv = Server::with_config(
        reactor_css,
        ServerConfig {
            reactor: true,
            ..ServerConfig::default()
        },
    )
    .start(0)
    .unwrap();

    // Activations shaped for the sim model's cut widths (16 after
    // stage 1, 8 after stage 2).
    let act = |n: usize, w: usize| {
        let data: Vec<f32> = (0..n * w).map(|i| (i as f32) * 0.13 - 0.9).collect();
        HostTensor::new(vec![n, w], data).unwrap()
    };
    let stream = vec![
        Request::InferPartial {
            split: 1,
            branch_state: BRANCH_PENDING,
            activation: act(2, 16),
        },
        Request::InferPartialSeq {
            seq: 7,
            split: 2,
            branch_state: BRANCH_GATED,
            encoding: WireEncoding::Raw,
            activation: act(1, 8),
        },
        Request::Ping,
    ];

    let a = exchange(thread_srv.addr(), &stream);
    let b = exchange(reactor_srv.addr(), &stream);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(normalized(x), normalized(y), "frame {i} diverged");
    }

    thread_srv.stop();
    reactor_srv.stop();
}

/// Per-connection window backpressure on the reactor: pipelining past
/// `conn_window` answers THROTTLE for the overflow while the admitted
/// request still completes — and responses stay in request order.
#[cfg(target_os = "linux")]
#[test]
fn reactor_window_throttles_pipelined_overflow() {
    // Slow stages so the first inference is still in flight when the
    // overflow frames (sent in the same TCP segment) are parsed.
    let fleet = Arc::new(sim_fleet(Duration::from_millis(20)));
    let handle = Server::with_config(
        fleet.clone(),
        ServerConfig {
            reactor: true,
            conn_window: 1,
            ..ServerConfig::default()
        },
    )
    .start(0)
    .unwrap();

    let img = inputs(1).pop().unwrap();
    let mut burst = Vec::new();
    for _ in 0..4 {
        write_frame(&mut burst, &Request::Infer(img.clone()).encode()).unwrap();
    }
    let mut stream = TcpStream::connect(handle.addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.write_all(&burst).unwrap(); // one segment: 4 pipelined frames
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // Request order is preserved: the admitted inference answers first,
    // then the three over-window THROTTLEs queued behind it.
    let first = Response::decode(&read_frame(&mut reader).unwrap()).unwrap();
    assert!(matches!(first, Response::Result { .. }), "{first:?}");
    for i in 0..3 {
        let r = Response::decode(&read_frame(&mut reader).unwrap()).unwrap();
        assert_eq!(
            r,
            Response::Throttle {
                retry_after_ms: THROTTLE_RETRY_AFTER_MS
            },
            "overflow frame {i}"
        );
    }
    assert_eq!(handle.stats().snapshot().throttled, 3);

    // The reactor path also stops promptly with this connection open.
    let t0 = Instant::now();
    handle.stop();
    assert!(t0.elapsed() < Duration::from_secs(5), "{:?}", t0.elapsed());
}

// ---------------------------------------------------------------------
// K-tier chain frames (kind 6): wire hygiene, then the three-listener
// pass-through bit-identity proof over live sockets.
// ---------------------------------------------------------------------

/// Kind-6 (INFER_CHAIN_SEQ) wire hygiene: the frame round-trips through
/// the framed wire exactly, `Request::encode` and the borrowing fast
/// path cannot drift, and every malformed-body class — truncated
/// header, zero cuts, over-cap cuts, truncated cut array, non-monotone
/// cuts, bad branch state, garbage tensor — is rejected with a loud,
/// specific error instead of being misparsed.
#[test]
fn chain_seq_frames_round_trip_and_reject_malformed_bodies() {
    use branchyserve::network::WireEncoding;
    use branchyserve::server::protocol::{
        encode_infer_chain_seq, BRANCH_GATED, BRANCH_PENDING, MAX_CHAIN_TIERS,
    };

    let act =
        HostTensor::new(vec![2, 16], (0..32).map(|i| i as f32 * 0.13 - 0.9).collect()).unwrap();
    let req = Request::InferChainSeq {
        seq: 42,
        cuts: vec![1, 1, 2],
        branch_state: BRANCH_GATED,
        encoding: WireEncoding::Raw,
        activation: act.clone(),
    };

    // Round-trip through the framed wire.
    let mut buf = Vec::new();
    write_frame(&mut buf, &req.encode()).unwrap();
    let body = read_frame(&mut &buf[..]).unwrap();
    assert_eq!(Request::decode(&body).unwrap(), req);

    // `Request::encode` delegates to the borrowing encoder: bit-equal.
    assert_eq!(
        req.encode(),
        encode_infer_chain_seq(42, &[1, 1, 2], BRANCH_GATED, WireEncoding::Raw, &act)
    );

    // seq and cuts really live on the wire: changing either changes bytes.
    let mut reseq = req.clone();
    if let Request::InferChainSeq { seq, .. } = &mut reseq {
        *seq = 43;
    }
    assert_ne!(reseq.encode(), req.encode());
    let mut recut = req.clone();
    if let Request::InferChainSeq { cuts, .. } = &mut recut {
        cuts[2] = 3;
    }
    assert_ne!(recut.encode(), req.encode());

    let err = |body: &[u8]| Request::decode(body).unwrap_err().to_string();

    // Truncated header (seq + ncuts = 8 bytes after the kind byte).
    assert!(err(&[6]).contains("truncated INFER_CHAIN_SEQ header"));
    assert!(err(&[6, 42, 0, 0, 0]).contains("truncated INFER_CHAIN_SEQ header"));
    // Zero cuts is meaningless.
    assert!(err(&[6, 42, 0, 0, 0, 0, 0, 0, 0]).contains("INFER_CHAIN_SEQ with no cuts"));
    // The tier cap bounds attacker-controlled cut counts.
    let too_many = encode_infer_chain_seq(
        1,
        &vec![2; MAX_CHAIN_TIERS + 1],
        BRANCH_PENDING,
        WireEncoding::Raw,
        &act,
    );
    assert!(err(&too_many).contains("exceeds cap"));
    // Cut array cut short: ncuts promises 3, the bytes carry 1.
    let valid = encode_infer_chain_seq(7, &[1, 1, 2], BRANCH_PENDING, WireEncoding::Raw, &act);
    assert!(err(&valid[..1 + 8 + 4]).contains("truncated INFER_CHAIN_SEQ cuts"));
    // Non-monotone cut vectors never reach a backend.
    let decreasing = encode_infer_chain_seq(7, &[3, 1], BRANCH_PENDING, WireEncoding::Raw, &act);
    assert!(err(&decreasing).contains("not non-decreasing"));
    // The branch_state byte sits right after the cuts: corrupt it in place.
    let mut bad_state = valid.clone();
    bad_state[1 + 8 + 12] = 9;
    assert!(err(&bad_state).contains("invalid branch_state"));
    // A garbage tensor payload fails in the tensor decoder, not silently.
    assert!(Request::decode(&valid[..valid.len() - 3]).is_err());
}

/// The satellite proof over real sockets: the same activations are
/// driven through a forwarding middle tier (kind-6 frames, terminal
/// tier behind its own listener) and through a plain single-hop server
/// (kind-5 frames). A pass-through middle (`cuts[0] == cuts[1]`), a
/// genuine two-segment chain, and a tail ending at the middle must all
/// answer classes/entropies bit-identical to the single hop, and the
/// per-hop split counters must land exactly at the planned cuts —
/// nowhere else.
#[test]
fn chain_pass_through_over_live_listeners_matches_single_hop_bitwise() {
    use branchyserve::network::WireEncoding;
    use branchyserve::server::protocol::{BRANCH_GATED, BRANCH_PENDING};
    use branchyserve::server::{CloudStageServer, RemoteCloudConfig, RemoteCloudEngine};

    // All three engines share the manifest name, hence deterministic
    // weights: segment composition across listeners must reproduce one
    // straight suffix run on any of them.
    let css = |label: &str| {
        CloudStageServer::new(InferenceEngine::open_sim(front_manifest(), label).unwrap())
    };
    let terminal = Arc::new(css("chain-term"));
    let term_srv = Server::new(terminal.clone()).start(0).unwrap();
    let forward = Arc::new(RemoteCloudEngine::new(RemoteCloudConfig::new(
        term_srv.addr().to_string(),
    )));
    let middle = Arc::new(css("chain-mid").with_forward(forward));
    let mid_srv = Server::new(middle.clone()).start(0).unwrap();
    let single = Arc::new(css("chain-single"));
    let single_srv = Server::new(single.clone()).start(0).unwrap();

    // Activations shaped for the sim model's cut widths (16 after
    // stage 1, 8 after stage 2).
    let act = |n: usize, w: usize| {
        let data: Vec<f32> = (0..n * w).map(|i| (i as f32) * 0.13 - 0.9).collect();
        HostTensor::new(vec![n, w], data).unwrap()
    };

    // Frame 1: pass-through middle (zero stages here, the terminal does
    // all the work). Frame 2: genuine chain (middle runs stage 2, the
    // terminal stage 3). Frame 3: the tail already covers the model, so
    // the middle answers it locally as a plain partial.
    let via_chain = exchange(
        mid_srv.addr(),
        &[
            Request::InferChainSeq {
                seq: 1,
                cuts: vec![1, 1],
                branch_state: BRANCH_PENDING,
                encoding: WireEncoding::Raw,
                activation: act(2, 16),
            },
            Request::InferChainSeq {
                seq: 2,
                cuts: vec![1, 2],
                branch_state: BRANCH_GATED,
                encoding: WireEncoding::Raw,
                activation: act(3, 16),
            },
            Request::InferChainSeq {
                seq: 3,
                cuts: vec![2, 3],
                branch_state: BRANCH_GATED,
                encoding: WireEncoding::Raw,
                activation: act(1, 8),
            },
            Request::Ping,
        ],
    );
    let via_single = exchange(
        single_srv.addr(),
        &[
            Request::InferPartialSeq {
                seq: 1,
                split: 1,
                branch_state: BRANCH_PENDING,
                encoding: WireEncoding::Raw,
                activation: act(2, 16),
            },
            Request::InferPartialSeq {
                seq: 2,
                split: 1,
                branch_state: BRANCH_GATED,
                encoding: WireEncoding::Raw,
                activation: act(3, 16),
            },
            Request::InferPartialSeq {
                seq: 3,
                split: 2,
                branch_state: BRANCH_GATED,
                encoding: WireEncoding::Raw,
                activation: act(1, 8),
            },
            Request::Ping,
        ],
    );
    assert_eq!(via_chain.len(), via_single.len());
    for (i, (chain, one_hop)) in via_chain.iter().zip(&via_single).enumerate() {
        assert_eq!(normalized(chain), normalized(one_hop), "frame {i} diverged");
    }

    // Per-hop accounting: every transfer happened exactly at its
    // planned cut. The middle saw cut 1 twice (frames 1–2) and served
    // frame 3 locally at cut 2; the terminal saw the forwarded tails at
    // cuts 1 and 2; the single-hop reference mirrors the middle's shape.
    assert_eq!(middle.chain_counters(), (2, 2));
    assert_eq!(middle.splits_served(), vec![0, 2, 1]);
    assert_eq!(terminal.chain_counters(), (0, 0));
    assert_eq!(terminal.splits_served(), vec![0, 1, 1]);
    assert_eq!(single.splits_served(), vec![0, 2, 1]);
    let (_, mid_samples, mid_gated, _, mid_errors) = middle.counters();
    assert_eq!((mid_samples, mid_gated, mid_errors), (6, 2, 0));

    // A genuine tail arriving at a tier with no forward engine answers
    // a seq-bound error, and the connection survives to serve the next
    // frame.
    let bodies = exchange(
        single_srv.addr(),
        &[
            Request::InferChainSeq {
                seq: 9,
                cuts: vec![0, 1],
                branch_state: BRANCH_PENDING,
                encoding: WireEncoding::Raw,
                activation: act(1, 4),
            },
            Request::Ping,
        ],
    );
    match Response::decode(&bodies[0]).unwrap() {
        Response::ErrorSeq { seq, message } => {
            assert_eq!(seq, 9);
            assert!(message.contains("terminal tier"), "{message}");
        }
        other => panic!("expected ErrorSeq, got {other:?}"),
    }
    assert_eq!(Response::decode(&bodies[1]).unwrap(), Response::Pong);

    mid_srv.stop();
    term_srv.stop();
    single_srv.stop();
}
