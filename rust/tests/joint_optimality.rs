//! Exhaustive-oracle layer for the joint configuration search
//! (`Planner::plan_joint`): on nets small enough to brute-force, the
//! joint result must be **bit-identical** to the argmin over every
//! (branch-set, wire-encoding, split) triple, where each triple is
//! priced independently by the standalone `Estimator` — a fresh, fully
//! validated desc per candidate, nothing shared with the planner's
//! cheap-view machinery under test.
//!
//! The oracle replicates the search's two documented tie-breaks and
//! nothing else: within a candidate, cut options carry `+epsilon` and
//! `<=` resolves exact ties toward the larger split; across candidates,
//! strict `<` keeps the earlier candidate in enumeration order. The
//! grids are seeded and include the degenerate corners the planner
//! clamps — 0 Mbps uplinks, infinite RTT — and exit probabilities at
//! exactly 0 and 1.
//!
//! A second oracle cross-checks the pricing itself: re-pricing a
//! candidate at its *encoded* byte sizes through the paper-faithful
//! `G'_BDNN` + Dijkstra solver must agree with the enumerated optimum.

use branchyserve::model::{synthetic, BranchDesc, BranchyNetDesc};
use branchyserve::network::bandwidth::LinkModel;
use branchyserve::network::encoding::WireEncoding;
use branchyserve::partition::solver;
use branchyserve::planner::joint::accuracy_proxy;
use branchyserve::planner::{JointSearchSpace, Planner};
use branchyserve::testing::{property, Gen};
use branchyserve::timing::{DelayProfile, Estimator};

const EPS: f64 = 1e-9;

/// Degenerate corners included in every link grid: a dead uplink
/// (clamped to the model's 1e-3 Mbps floor), a starved 3G-ish link, the
/// paper's profiles, and an effectively infinite pipe.
const BANDWIDTHS_MBPS: [f64; 6] = [0.0, 1e-3, 0.5, 1.10, 18.80, 1e5];
/// RTT corners, including an infinite RTT (clamped by the link model).
const RTTS_S: [f64; 5] = [0.0, 0.005, 0.1, 60.0, f64::INFINITY];

/// The brute-force winner over every (branch-set, encoding, split)
/// triple, plus the bookkeeping `plan_joint` must also reproduce.
struct Oracle {
    branch_set: Vec<BranchDesc>,
    encoding: WireEncoding,
    split: usize,
    expected_time: f64,
    accuracy_proxy: f64,
    pruned: usize,
    survivors: usize,
}

/// Price one (branch-set, encoding) candidate by exhaustive split
/// enumeration through a fresh `Estimator` on a fresh desc — the
/// independent implementation of the cost model. Applies the same
/// epsilon decision rule as `plan_for`: cut options (s < N) carry
/// `+epsilon`, `<=` resolves exact ties toward the larger split.
fn enumerate_splits(
    desc_b: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    encoding: WireEncoding,
    epsilon: f64,
    paper_mode: bool,
) -> (usize, f64) {
    let mut est = Estimator::new(desc_b, profile, link).with_encoding(encoding);
    if paper_mode {
        est = est.paper_mode();
    }
    let n = desc_b.num_stages();
    let mut best_split = 0usize;
    let mut best_model = f64::INFINITY;
    let mut best_decision = f64::INFINITY;
    for s in 0..=n {
        let model = est.expected_time(s);
        let decision = if s < n { model + epsilon } else { model };
        if decision <= best_decision {
            best_decision = decision;
            best_model = model;
            best_split = s;
        }
    }
    (best_split, best_model)
}

/// The full brute force: every triple, in `space` enumeration order,
/// strict `<` across candidates (first wins exact ties). Returns None
/// when the floor prunes everything (`plan_joint` panics there — the
/// callers below never construct that case without expecting it).
fn brute_force(
    desc_template: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    space: &JointSearchSpace,
    epsilon: f64,
    paper_mode: bool,
) -> Option<Oracle> {
    let mut best: Option<Oracle> = None;
    let mut pruned = 0usize;
    let mut survivors = 0usize;
    for set in &space.branch_sets {
        let mut branches = set.clone();
        branches.sort_by_key(|b| b.after_stage);
        let proxy = accuracy_proxy(&branches);
        if proxy < space.min_accuracy_proxy {
            pruned += 1;
            continue;
        }
        let mut desc_b = desc_template.clone();
        desc_b.branches = branches.clone();
        for &encoding in &space.encodings {
            survivors += 1;
            let (split, time) = enumerate_splits(&desc_b, profile, link, encoding, epsilon, paper_mode);
            let wins = match &best {
                None => true,
                Some(b) => time < b.expected_time,
            };
            if wins {
                best = Some(Oracle {
                    branch_set: branches.clone(),
                    encoding,
                    split,
                    expected_time: time,
                    accuracy_proxy: proxy,
                    pruned: 0,
                    survivors: 0,
                });
            }
        }
    }
    best.map(|mut b| {
        b.pruned = pruned;
        b.survivors = survivors;
        b
    })
}

fn assert_matches_oracle(
    planner: &Planner,
    link: LinkModel,
    space: &JointSearchSpace,
    want: &Oracle,
    ctx: &str,
) {
    let joint = planner.plan_joint(link, space);
    assert_eq!(joint.branch_set, want.branch_set, "branch set ({ctx})");
    assert_eq!(joint.encoding, want.encoding, "encoding ({ctx})");
    assert_eq!(joint.split, want.split, "split ({ctx})");
    assert_eq!(
        joint.expected_time.to_bits(),
        want.expected_time.to_bits(),
        "expected time {} vs oracle {} ({ctx})",
        joint.expected_time,
        want.expected_time
    );
    assert_eq!(
        joint.accuracy_proxy.to_bits(),
        want.accuracy_proxy.to_bits(),
        "accuracy proxy ({ctx})"
    );
    assert_eq!(joint.pruned, want.pruned, "pruned count ({ctx})");
    assert_eq!(
        joint.ranked.len(),
        want.survivors,
        "ranked table must cover every surviving (set, encoding) pair ({ctx})"
    );
    for pair in joint.ranked.windows(2) {
        assert!(
            pair[0].expected_time <= pair[1].expected_time,
            "ranked table out of order ({ctx})"
        );
    }
}

/// Random candidate branch sets: up to `max_sets` sets of 0..=3
/// branches at distinct interior positions, probabilities from the
/// endpoint-hitting generator (exact 0.0 and 1.0 occur).
fn random_branch_sets(g: &mut Gen, n: usize, max_sets: usize) -> Vec<Vec<BranchDesc>> {
    let n_sets = g.usize_in(1, max_sets);
    (0..n_sets)
        .map(|_| {
            let mut slots: Vec<usize> = (1..n).collect();
            for i in (1..slots.len()).rev() {
                let j = g.usize_in(0, i);
                slots.swap(i, j);
            }
            let k = g.usize_in(0, slots.len().min(3));
            slots[..k]
                .iter()
                .map(|&after_stage| BranchDesc {
                    after_stage,
                    exit_prob: g.probability(),
                })
                .collect()
        })
        .collect()
}

/// The tentpole obligation: on seeded random instances — net, profile,
/// candidate sets, accuracy floor, epsilon, link (degenerate corners
/// included) — `plan_joint` is bit-identical to the brute-force argmin
/// over every triple.
#[test]
fn joint_is_bit_identical_to_the_exhaustive_argmin() {
    property("plan_joint == brute force", 120, |g| {
        let n = g.usize_in(2, 10);
        let desc = synthetic::random_desc(g, n, 3);
        let profile = synthetic::random_profile(g, &desc, g.f64_in(1.0, 500.0));
        let paper = g.bool(0.5);
        let epsilon = *g.choose(&[1e-12, 1e-9, 1e-3]);
        let planner = Planner::new(&desc, &profile, epsilon, paper);

        let branch_sets = random_branch_sets(g, n, 3);
        let mut space = JointSearchSpace {
            branch_sets,
            encodings: WireEncoding::ALL.to_vec(),
            min_accuracy_proxy: if g.bool(0.5) { 0.0 } else { g.f64_in(0.0, 1.0) },
        };
        // Keep at least one survivor: `plan_joint` treats an
        // all-pruning floor as a caller error (it panics).
        let max_proxy = space
            .branch_sets
            .iter()
            .map(|s| accuracy_proxy(s))
            .fold(f64::NEG_INFINITY, f64::max);
        if max_proxy < space.min_accuracy_proxy {
            space.min_accuracy_proxy = 0.0;
        }

        let link = LinkModel::new(*g.choose(&BANDWIDTHS_MBPS), *g.choose(&RTTS_S));
        let want = brute_force(&desc, &profile, link, &space, epsilon, paper)
            .expect("floor was adjusted to keep a survivor");
        let ctx = format!(
            "n={n} paper={paper} eps={epsilon} link={:.4}Mbps/{:.3}s floor={}",
            link.uplink_mbps, link.rtt_s, space.min_accuracy_proxy
        );
        assert_matches_oracle(&planner, link, &space, &want, &ctx);
    });
}

/// The same obligation on a pinned grid of degenerate corners — no
/// randomness, every combination visited: dead/infinite links ×
/// zero/infinite RTT × exit probabilities at exactly 0 and 1 × both
/// planner modes. Failures here reproduce without a seed.
#[test]
fn degenerate_corners_match_the_oracle_exhaustively() {
    let b = |after_stage: usize, exit_prob: f64| BranchDesc {
        after_stage,
        exit_prob,
    };
    let desc = BranchyNetDesc {
        stage_names: (1..=6).map(|i| format!("s{i}")).collect(),
        stage_out_bytes: vec![57_600, 18_816, 25_088, 3_456, 1_024, 8],
        input_bytes: 12_288,
        branches: vec![b(1, 0.5)],
    };
    let profile = DelayProfile::from_cloud_times(
        vec![1e-3, 1.5e-3, 1.2e-3, 8e-4, 3e-4, 5e-5],
        2e-4,
        10.0,
    );
    let space = JointSearchSpace {
        branch_sets: vec![
            vec![],                      // plain DNN, proxy 1.0
            vec![b(1, 0.0), b(3, 1.0)],  // a dead branch and a total one
            vec![b(2, 0.5)],
            vec![b(5, 1.0)],             // everything exits at the last slot
        ],
        encodings: WireEncoding::ALL.to_vec(),
        min_accuracy_proxy: 0.0,
    };
    for paper in [true, false] {
        let planner = Planner::new(&desc, &profile, EPS, paper);
        for &mbps in &BANDWIDTHS_MBPS {
            for &rtt in &RTTS_S {
                let link = LinkModel::new(mbps, rtt);
                let want = brute_force(&desc, &profile, link, &space, EPS, paper)
                    .expect("floor 0 never prunes");
                let ctx = format!("paper={paper} mbps={mbps} rtt={rtt}");
                assert_matches_oracle(&planner, link, &space, &want, &ctx);
            }
        }
    }
}

/// Pricing cross-check through an independent solver: a candidate's
/// encoded transfer sizes, baked *into the desc as raw bytes*, must
/// make (a) the Raw-priced `Estimator` bit-identical to the
/// encoding-priced one on the original desc at every split, and (b)
/// the paper-faithful `G'_BDNN` + Dijkstra solver agree with the
/// enumerated optimum up to the epsilon tie-break.
#[test]
fn faithful_solver_agrees_on_encoded_byte_sizes() {
    property("solve_faithful == enumerated optimum at encoded bytes", 60, |g| {
        let n = g.usize_in(2, 10);
        let desc = synthetic::random_desc(g, n, 3);
        let profile = synthetic::random_profile(g, &desc, g.f64_in(1.0, 500.0));
        let paper = g.bool(0.5);
        let link = LinkModel::new(*g.choose(&BANDWIDTHS_MBPS), *g.choose(&RTTS_S));

        for set in random_branch_sets(g, n, 2) {
            let mut desc_b = desc.clone();
            desc_b.branches = {
                let mut s = set.clone();
                s.sort_by_key(|b| b.after_stage);
                s
            };
            for &encoding in &WireEncoding::ALL {
                // The byte-mapped desc: every transferable size pushed
                // through the encoding's size map, so Raw pricing on it
                // *is* encoded pricing on the original.
                let mut mapped = desc_b.clone();
                mapped.input_bytes = encoding.payload_bytes(desc_b.input_bytes);
                for bytes in &mut mapped.stage_out_bytes {
                    *bytes = encoding.payload_bytes(*bytes);
                }

                let mut enc_est = Estimator::new(&desc_b, &profile, link).with_encoding(encoding);
                let mut raw_est = Estimator::new(&mapped, &profile, link);
                if paper {
                    enc_est = enc_est.paper_mode();
                    raw_est = raw_est.paper_mode();
                }
                for s in 0..=n {
                    assert_eq!(
                        raw_est.expected_time(s).to_bits(),
                        enc_est.expected_time(s).to_bits(),
                        "byte-mapped Raw pricing must equal encoded pricing \
                         (split {s}, {encoding:?}, n={n})"
                    );
                }

                let (best_split, best_time) =
                    enumerate_splits(&desc_b, &profile, link, encoding, EPS, paper);
                let faithful = solver::solve_faithful(&mapped, &profile, link, EPS, paper);
                // Same optimum up to the tie-break epsilon plus fp noise
                // between the two summation orders; identical split
                // means identical bits.
                let tol = EPS + 1e-9 * faithful.expected_time_s.abs().max(1.0);
                assert!(
                    (faithful.expected_time_s - best_time).abs() <= tol,
                    "faithful {} vs enumerated {} ({encoding:?}, n={n})",
                    faithful.expected_time_s,
                    best_time
                );
                if faithful.split_after == best_split {
                    assert_eq!(
                        faithful.expected_time_s.to_bits(),
                        best_time.to_bits(),
                        "same split must price identically ({encoding:?}, n={n})"
                    );
                }
            }
        }
    });
}

/// The floor bookkeeping against the oracle: with a floor sitting
/// strictly between two candidates' proxies, exactly the low-proxy set
/// is pruned and the survivor wins regardless of latency order.
#[test]
fn floor_prunes_exactly_the_low_proxy_sets() {
    let b = |after_stage: usize, exit_prob: f64| BranchDesc {
        after_stage,
        exit_prob,
    };
    let desc = BranchyNetDesc {
        stage_names: (1..=5).map(|i| format!("s{i}")).collect(),
        stage_out_bytes: vec![57_600, 18_816, 25_088, 3_456, 8],
        input_bytes: 12_288,
        branches: vec![b(1, 0.5)],
    };
    let profile =
        DelayProfile::from_cloud_times(vec![1e-3, 2e-3, 1.5e-3, 8e-4, 2e-4], 3e-4, 100.0);
    let planner = Planner::new(&desc, &profile, EPS, true);
    let space = JointSearchSpace {
        branch_sets: vec![vec![b(1, 0.9)], vec![b(2, 0.3)], vec![b(1, 0.95)]],
        encodings: WireEncoding::ALL.to_vec(),
        min_accuracy_proxy: 0.5,
    };
    for &mbps in &BANDWIDTHS_MBPS {
        let link = LinkModel::new(mbps, 0.01);
        let want = brute_force(&desc, &profile, link, &space, EPS, true).unwrap();
        assert_eq!(want.pruned, 2, "proxies 0.1 and 0.05 sit under the 0.5 floor");
        assert_eq!(want.survivors, WireEncoding::ALL.len());
        assert_matches_oracle(&planner, link, &space, &want, &format!("mbps={mbps}"));
    }
}
