"""Build-time training of B-AlexNet with the BranchyNet joint loss.

Runs once inside `make artifacts` (never on the request path). Trains on
the procedural cat/dog-like dataset (data.py) with the joint objective of
the BranchyNet paper [5]:

    L = CE(main_logits, y) + w_branch * CE(branch_logits, y)

so the side branch learns a usable classifier. SGD with momentum on the
pure-jnp (ref-op) forward — XLA fuses it well on CPU; the Pallas-kernel
forward computes the identical function (asserted by the kernel tests) and
is what gets exported by aot.py.

Outputs: <out>/weights.npz (flat {path: array}) + training_log.json.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model

BRANCH_LOSS_WEIGHT = 0.5
LR = 0.01
MOMENTUM = 0.9
GRAD_CLIP = 5.0  # global-norm clip: keeps early high-loss steps stable
BATCH = 64
STEPS = 400
TRAIN_N = 4096
TEST_N = 512
SEED = 7


LABEL_SMOOTH = 0.08  # keeps confidence off the simplex corner so branch
# entropy has a usable dynamic range (Fig. 6 threshold sweep)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    n = logits.shape[-1]
    onehot = jax.nn.one_hot(labels, n)
    target = onehot * (1.0 - LABEL_SMOOTH) + LABEL_SMOOTH / n
    return -jnp.mean(jnp.sum(target * logp, axis=-1))


def joint_loss(params: dict, x: jax.Array, y: jax.Array) -> jax.Array:
    branch_logits, main_logits = model.forward_both(params, x, use_pallas=False)
    return cross_entropy(main_logits, y) + BRANCH_LOSS_WEIGHT * cross_entropy(
        branch_logits, y
    )


@jax.jit
def train_step(params: dict, vel: dict, x: jax.Array, y: jax.Array):
    loss, grads = jax.value_and_grad(joint_loss)(params, x, y)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g**2) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, GRAD_CLIP / (gnorm + 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)
    vel = jax.tree.map(lambda v, g: MOMENTUM * v - LR * g, vel, grads)
    params = jax.tree.map(lambda p, v: p + v, params, vel)
    return params, vel, loss


@jax.jit
def eval_step(params: dict, x: jax.Array, y: jax.Array):
    branch_logits, main_logits = model.forward_both(params, x, use_pallas=False)
    bacc = jnp.mean((jnp.argmax(branch_logits, -1) == y).astype(jnp.float32))
    macc = jnp.mean((jnp.argmax(main_logits, -1) == y).astype(jnp.float32))
    return bacc, macc


def flatten_params(params: dict) -> dict[str, np.ndarray]:
    return {
        f"{stage}/{leaf}": np.asarray(arr)
        for stage, leaves in params.items()
        for leaf, arr in leaves.items()
    }


def unflatten_params(flat: dict[str, np.ndarray]) -> dict:
    params: dict = {}
    for key, arr in flat.items():
        stage, leaf = key.split("/")
        params.setdefault(stage, {})[leaf] = jnp.asarray(arr)
    return params


def load_weights(path: Path) -> dict:
    with np.load(path) as z:
        return unflatten_params({k: z[k] for k in z.files})


def train(out_dir: Path, steps: int = STEPS, seed: int = SEED) -> dict:
    t0 = time.time()
    xs, ys = data.make_dataset(TRAIN_N, seed=seed)
    xt, yt = data.make_dataset(TEST_N, seed=seed + 1)
    xs, ys, xt, yt = map(jnp.asarray, (xs, ys, xt, yt))

    params = model.init_params(jax.random.PRNGKey(seed))
    vel = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed)

    log: list[dict] = []
    for step in range(steps):
        idx = rng.integers(0, TRAIN_N, size=BATCH)
        params, vel, loss = train_step(params, vel, xs[idx], ys[idx])
        if step % 50 == 0 or step == steps - 1:
            bacc, macc = eval_step(params, xt, yt)
            rec = {
                "step": step,
                "loss": float(loss),
                "branch_acc": float(bacc),
                "main_acc": float(macc),
            }
            log.append(rec)
            print(
                f"step {step:4d}  loss {rec['loss']:.4f}  "
                f"branch_acc {rec['branch_acc']:.3f}  main_acc {rec['main_acc']:.3f}"
            )

    out_dir.mkdir(parents=True, exist_ok=True)
    np.savez(out_dir / "weights.npz", **flatten_params(params))
    (out_dir / "training_log.json").write_text(
        json.dumps(
            {
                "steps": steps,
                "batch": BATCH,
                "lr": LR,
                "momentum": MOMENTUM,
                "branch_loss_weight": BRANCH_LOSS_WEIGHT,
                "wall_seconds": time.time() - t0,
                "history": log,
            },
            indent=2,
        )
    )
    return params


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", type=Path, default=Path("../artifacts"))
    ap.add_argument("--steps", type=int, default=STEPS)
    args = ap.parse_args()
    train(args.out, steps=args.steps)


if __name__ == "__main__":
    main()
