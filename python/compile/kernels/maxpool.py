"""Pallas max-pooling kernel (NCHW, square window, VALID padding).

The grid walks (N, C/bc): each step holds one (bc, H, W) channel slab in
VMEM and computes every output pixel from ``window**2`` statically-unrolled
shifted strided views reduced with ``jnp.maximum`` — an 8x128-lane-friendly
elementwise max tree on the VPU, with no gather and no HBM re-reads (each
input element is touched once per overlapping window from VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref, *, window: int, stride: int, oh: int, ow: int):
    x = x_ref[...]  # (1, bc, H, W)
    acc = None
    for i in range(window):
        for j in range(window):
            view = jax.lax.slice(
                x,
                (0, 0, i, j),
                (1, x.shape[1], i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1),
                (1, 1, stride, stride),
            )
            acc = view if acc is None else jnp.maximum(acc, view)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("window", "stride", "block_c"))
def maxpool2d(
    x: jax.Array, window: int = 3, stride: int = 2, block_c: int = 32
) -> jax.Array:
    """NCHW max-pool; x: (N, C, H, W) -> (N, C, OH, OW), VALID padding."""
    n, c, h, w = x.shape
    if h < window or w < window:
        raise ValueError(f"input {h}x{w} smaller than window {window}")
    oh = (h - window) // stride + 1
    ow = (w - window) // stride + 1

    bc = min(block_c, c)
    # Pad channels to a block multiple; padded channels are garbage but get
    # sliced off below (maxpool is channelwise, no cross-contamination).
    cp = (c + bc - 1) // bc * bc
    xp = jnp.pad(x, ((0, 0), (0, cp - c), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(
            _maxpool_kernel, window=window, stride=stride, oh=oh, ow=ow
        ),
        grid=(n, cp // bc),
        in_specs=[pl.BlockSpec((1, bc, h, w), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, bc, oh, ow), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, cp, oh, ow), x.dtype),
        interpret=True,
    )(xp)
    return out[:, :c]
