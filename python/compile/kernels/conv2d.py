"""Pallas conv2d: im2col unfold + the blocked MXU matmul kernel.

Hardware adaptation (DESIGN.md §8): instead of porting a CUDA-style
implicit-GEMM with threadblock tiles, the convolution is expressed the TPU
way — an explicit im2col reshuffle (pure layout work that XLA fuses into
cheap strided slices) followed by one large (N*OH*OW, C*KH*KW) x
(C*KH*KW, O) contraction on the MXU via ``kernels.matmul``. Bias and ReLU
ride the matmul epilogue, so a conv layer is a single fused kernel pass
over its data.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import matmul
from . import ref


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    stride: int = 1,
    padding: int = 0,
    act: str = "none",
) -> jax.Array:
    """NCHW conv2d with square kernel, bias and optional ReLU.

    x: (N, C, H, W); w: (O, C, KH, KW); b: (O,). Returns (N, O, OH, OW).
    """
    n, c, h, wdt = x.shape
    o, c2, kh, kw = w.shape
    if c != c2:
        raise ValueError(f"channel mismatch: input {c} vs weight {c2}")
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (wdt + 2 * padding - kw) // stride + 1

    cols = ref.im2col(x, kh, kw, stride, padding)  # (N*OH*OW, C*KH*KW)
    # OIHW -> (C*KH*KW, O). im2col column order is (C, KH*KW) — channel
    # outer, window offset inner — so weight rows must match it.
    wmat = w.reshape(o, c, kh * kw).transpose(1, 2, 0).reshape(c * kh * kw, o)
    out = matmul.matmul_bias_act(cols, wmat, b, act=act)  # (N*OH*OW, O)
    return out.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)
