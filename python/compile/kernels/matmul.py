"""Blocked Pallas matmul with fused bias + activation epilogue.

This is the workhorse kernel: both the fully-connected layers and the
im2col-lowered convolutions of B-AlexNet reduce to it.

TPU mapping (see DESIGN.md §8): the grid walks (M/bm, N/bn, K/bk); each
step keeps one (bm, bk) LHS panel, one (bk, bn) RHS panel and the (bm, bn)
output tile in VMEM and issues a single (bm x bk) @ (bk x bn) MXU
contraction. The K axis is innermost and the output tile's index map does
not depend on k, so the accumulator stays resident across the whole K sweep
(output-stationary schedule); bias-add and ReLU run as an epilogue on the
final K step, so the activation never makes an extra HBM round-trip.

Block sizes default to 128 (MXU systolic width) but shrink to the problem
when a dimension is smaller. All dims are zero-padded up to block multiples
in the wrapper; zero K-padding is exact for matmul, and M/N padding is
sliced off afterwards.

On this testbed the kernel runs with ``interpret=True`` (CPU PJRT cannot
execute Mosaic custom-calls); correctness is asserted against
``ref.matmul_bias_act`` in the pytest suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _matmul_kernel(x_ref, y_ref, b_ref, o_ref, *, nsteps_k: int, act: str):
    """One grid step: o += x_tile @ y_tile; epilogue on the last K step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nsteps_k - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...]
        if act == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("act", "block_m", "block_n", "block_k"))
def matmul_bias_act(
    x: jax.Array,
    y: jax.Array,
    bias: jax.Array,
    act: str = "none",
    block_m: int = DEFAULT_BLOCK,
    block_n: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
) -> jax.Array:
    """(M, K) @ (K, N) + bias[N] with optional ReLU, as a Pallas kernel."""
    if act not in ("none", "relu"):
        raise ValueError(f"unknown activation: {act}")
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    if bias.shape != (n,):
        raise ValueError(f"bias shape {bias.shape} != ({n},)")

    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 8))
    bk = min(block_k, _round_up(k, 8))

    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(bias, (0, np_ - n))[None, :]  # (1, Np) row for broadcast

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nsteps_k=grid[2], act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp, bp)
    return out[:m, :n]


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Plain (M, K) @ (K, N) via the fused kernel with a zero bias."""
    return matmul_bias_act(x, y, jnp.zeros((y.shape[1],), jnp.float32), act="none")


def vmem_bytes(block_m: int, block_n: int, block_k: int) -> int:
    """Estimated VMEM residency per grid step (f32): LHS+RHS+bias+out tiles."""
    return 4 * (block_m * block_k + block_k * block_n + block_n + block_m * block_n)
