"""Fused Pallas softmax + Shannon-entropy kernel — the early-exit head.

BranchyNet's exit decision needs, per sample, the class-probability vector
and its entropy (the confidence statistic compared against the branch
threshold). Fusing them means the exit gate costs a single VMEM-resident
pass over the (batch, classes) logits: row max, exp, row sum, normalize,
and the entropy identity ``H = logsumexp(z) - sum(p * z)`` (z = shifted
logits), which never evaluates ``0 * log 0``.

The grid is 1-D over row blocks; classes stay un-tiled (C is tiny for a
classifier head, far under a VMEM lane tile), so each row's statistics are
computed in one step without cross-step reductions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_entropy_kernel(x_ref, p_ref, h_ref):
    z = x_ref[...]
    z = z - jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z)
    s = jnp.sum(e, axis=-1, keepdims=True)
    p = e / s
    p_ref[...] = p
    h_ref[...] = jnp.log(s) - jnp.sum(p * z, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_b",))
def softmax_entropy(
    logits: jax.Array, block_b: int = 128
) -> tuple[jax.Array, jax.Array]:
    """Row softmax + entropy (nats). logits: (B, C) -> ((B, C), (B,))."""
    b, c = logits.shape
    bb = min(block_b, b)
    bp = (b + bb - 1) // bb * bb
    xp = jnp.pad(logits, ((0, bp - b), (0, 0)))

    probs, ent = pl.pallas_call(
        _softmax_entropy_kernel,
        grid=(bp // bb,),
        in_specs=[pl.BlockSpec((bb, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, c), jnp.float32),
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        ],
        interpret=True,
    )(xp)
    return probs[:b], ent[:b, 0]
