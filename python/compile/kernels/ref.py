"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these implementations to f32 tolerance.

They are also the implementations used by the *training* path
(`compile/train.py`): training only runs at build time, where XLA's fused
`lax.conv` is much faster under CPU jit than interpret-mode Pallas. The
*exported* inference artifacts use the Pallas kernels, and the
kernel-vs-ref tests guarantee both paths compute the same function.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Plain f32 matmul: (M, K) @ (K, N) -> (M, N)."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def matmul_bias_act(
    x: jax.Array, y: jax.Array, bias: jax.Array, act: str = "none"
) -> jax.Array:
    """Matmul with fused bias-add and optional ReLU epilogue."""
    out = matmul(x, y) + bias[None, :]
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act != "none":
        raise ValueError(f"unknown activation: {act}")
    return out


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    stride: int = 1,
    padding: int = 0,
    act: str = "none",
) -> jax.Array:
    """NCHW conv2d with square kernel/stride/padding, bias and activation.

    x: (N, C, H, W); w: (O, C, KH, KW); b: (O,).
    """
    out = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    out = out + b[None, :, None, None]
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act != "none":
        raise ValueError(f"unknown activation: {act}")
    return out


def maxpool2d(x: jax.Array, window: int = 3, stride: int = 2) -> jax.Array:
    """NCHW max-pooling with square window/stride and VALID padding."""
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 1, window, window),
        window_strides=(1, 1, stride, stride),
        padding="VALID",
    )


def softmax(logits: jax.Array) -> jax.Array:
    """Numerically-stable row softmax over the last axis."""
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def softmax_entropy(logits: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused softmax + Shannon entropy (nats) over the last axis.

    Returns (probs, entropy). Entropy is computed as
    ``logsumexp(z) - sum(p * z)`` with ``z = logits - max`` which avoids
    ``0 * log 0`` and matches ``-sum(p log p)`` analytically.
    """
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    s = jnp.sum(e, axis=-1)
    p = e / s[..., None]
    lse = jnp.log(s)
    ent = lse - jnp.sum(p * z, axis=-1)
    return p, ent


def im2col(x: jax.Array, kh: int, kw: int, stride: int, padding: int) -> jax.Array:
    """Unfold NCHW input into patch-matrix form for matmul-based conv.

    Returns (N * OH * OW, C * KH * KW); column order matches a reshape of
    OIHW weights to (O, C*KH*KW) rows.
    """
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    # Gather all (kh, kw) shifted strided views; static python loops unroll
    # into cheap slices at trace time.
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = lax.slice(
                xp,
                (0, 0, i, j),
                (n, c, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1),
                (1, 1, stride, stride),
            )  # (N, C, OH, OW)
            cols.append(patch)
    # (KH*KW, N, C, OH, OW) -> (N, OH, OW, C, KH*KW)
    stacked = jnp.stack(cols, axis=0)
    stacked = stacked.transpose(1, 3, 4, 2, 0)  # N, OH, OW, C, KH*KW
    return stacked.reshape(n * oh * ow, c * kh * kw)
