"""L2: B-AlexNet — CIFAR-scale AlexNet main branch + one side branch.

This mirrors the paper's evaluation model (§VI): a standard AlexNet main
branch with a single side branch inserted after the first stage, trained
for a binary (cat-vs-dog-like) image task. Per DESIGN.md §4 we use the
32x32-input AlexNet variant (the scale the original BranchyNet paper [5]
used) so the network is trainable on CPU at build time while keeping the
non-monotonic per-layer output-size profile that drives the partitioning
trade-off:

    stage    out shape      alpha_i (f32 bytes, batch 1)
    input    (3, 32, 32)    12288
    conv1    (64, 15, 15)   57600   <- larger than the raw input!
    conv2    (96, 7, 7)     18816
    conv3    (128, 7, 7)    25088
    conv4    (128, 7, 7)    25088
    conv5    (96, 3, 3)     3456
    fc1      (256,)         1024
    fc2      (128,)         512
    fc3      (2,)           8

Every *stage* here is one vertex of the paper's main-branch chain graph
(conv stages fuse their ReLU and trailing max-pool, as is standard when
profiling partition points — a pool is never a useful split point because
it only shrinks data). The side branch ``b1`` hangs off stage 1.

Each stage has a pure function ``apply_stage(params, name, x, use_pallas)``
used by three consumers:
  * ``train.py``  — use_pallas=False (XLA-fused ref ops, fast CPU training)
  * ``aot.py``    — use_pallas=True  (Pallas kernels, the exported artifacts)
  * tests        — both, asserted equal.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import conv2d as pl_conv
from .kernels import matmul as pl_matmul
from .kernels import maxpool as pl_pool
from .kernels import softmax_entropy as pl_ent
from .kernels import ref

NUM_CLASSES = 2
INPUT_SHAPE = (3, 32, 32)  # CHW


@dataclass(frozen=True)
class ConvSpec:
    """A conv stage: conv(+bias+relu) followed by an optional max-pool."""

    name: str
    in_ch: int
    out_ch: int
    kernel: int
    stride: int = 1
    padding: int = 0
    pool: bool = False
    pool_window: int = 3
    pool_stride: int = 2


@dataclass(frozen=True)
class FcSpec:
    """A fully-connected stage; flattens its input if it is 4-D."""

    name: str
    in_dim: int
    out_dim: int
    act: str = "relu"


# Main-branch chain: one entry per partitionable vertex v_1..v_8.
STAGES: tuple = (
    ConvSpec("conv1", 3, 64, 5, 1, 2, pool=True),
    ConvSpec("conv2", 64, 96, 5, 1, 2, pool=True),
    ConvSpec("conv3", 96, 128, 3, 1, 1),
    ConvSpec("conv4", 128, 128, 3, 1, 1),
    ConvSpec("conv5", 128, 96, 3, 1, 1, pool=True),
    FcSpec("fc1", 96 * 3 * 3, 256),
    FcSpec("fc2", 256, 128),
    FcSpec("fc3", 128, NUM_CLASSES, act="none"),
)

STAGE_NAMES: tuple = tuple(s.name for s in STAGES)

# Side branch b1, inserted after stage index 1 (i.e. after conv1's pool),
# mirroring the paper's "one side branch after the first middle layer".
BRANCH_AFTER = 1  # 1-based stage index the branch consumes the output of
BRANCH_CONV = ConvSpec("b1_conv", 64, 32, 3, 1, 1, pool=True)
BRANCH_FC = FcSpec("b1_fc", 32 * 7 * 7, NUM_CLASSES, act="none")


def _conv_out_hw(h: int, w: int, s: ConvSpec) -> tuple[int, int]:
    oh = (h + 2 * s.padding - s.kernel) // s.stride + 1
    ow = (w + 2 * s.padding - s.kernel) // s.stride + 1
    if s.pool:
        oh = (oh - s.pool_window) // s.pool_stride + 1
        ow = (ow - s.pool_window) // s.pool_stride + 1
    return oh, ow


def stage_shapes() -> list[tuple[int, ...]]:
    """Output CHW/flat shape of every main-branch stage, in order."""
    shapes: list[tuple[int, ...]] = []
    c, h, w = INPUT_SHAPE
    for s in STAGES:
        if isinstance(s, ConvSpec):
            h, w = _conv_out_hw(h, w, s)
            c = s.out_ch
            shapes.append((c, h, w))
        else:
            shapes.append((s.out_dim,))
    return shapes


def branch_input_shape() -> tuple[int, ...]:
    return stage_shapes()[BRANCH_AFTER - 1]


def branch_output_shape() -> tuple[int, ...]:
    return (NUM_CLASSES,)


def output_bytes(shape: tuple[int, ...], dtype_bytes: int = 4) -> int:
    return int(math.prod(shape)) * dtype_bytes


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _init_conv(key, s: ConvSpec) -> dict:
    kw, _ = jax.random.split(key)
    fan_in = s.in_ch * s.kernel * s.kernel
    std = math.sqrt(2.0 / fan_in)  # He init for ReLU stacks
    return {
        "w": jax.random.normal(kw, (s.out_ch, s.in_ch, s.kernel, s.kernel)) * std,
        "b": jnp.zeros((s.out_ch,), jnp.float32),
    }


def _init_fc(key, s: FcSpec) -> dict:
    kw, _ = jax.random.split(key)
    std = math.sqrt(2.0 / s.in_dim)
    return {
        "w": jax.random.normal(kw, (s.in_dim, s.out_dim)) * std,
        "b": jnp.zeros((s.out_dim,), jnp.float32),
    }


def init_params(key: jax.Array) -> dict:
    """He-initialized parameter pytree: {stage_name: {w, b}} + branch."""
    keys = jax.random.split(key, len(STAGES) + 2)
    params: dict = {}
    for k, s in zip(keys[: len(STAGES)], STAGES):
        params[s.name] = _init_conv(k, s) if isinstance(s, ConvSpec) else _init_fc(k, s)
    params[BRANCH_CONV.name] = _init_conv(keys[-2], BRANCH_CONV)
    params[BRANCH_FC.name] = _init_fc(keys[-1], BRANCH_FC)
    return params


def param_count(params: dict) -> int:
    return sum(int(math.prod(v.shape)) for leaf in params.values() for v in leaf.values())


# ---------------------------------------------------------------------------
# Forward functions
# ---------------------------------------------------------------------------


def _apply_conv(p: dict, s: ConvSpec, x: jax.Array, use_pallas: bool) -> jax.Array:
    conv = pl_conv.conv2d if use_pallas else ref.conv2d
    pool = pl_pool.maxpool2d if use_pallas else ref.maxpool2d
    x = conv(x, p["w"], p["b"], stride=s.stride, padding=s.padding, act="relu")
    if s.pool:
        x = pool(x, s.pool_window, s.pool_stride)
    return x


def _apply_fc(p: dict, s: FcSpec, x: jax.Array, use_pallas: bool) -> jax.Array:
    if x.ndim > 2:
        x = x.reshape(x.shape[0], -1)
    mm = pl_matmul.matmul_bias_act if use_pallas else ref.matmul_bias_act
    return mm(x, p["w"], p["b"], act=s.act)


def apply_stage(params: dict, name: str, x: jax.Array, use_pallas: bool = False) -> jax.Array:
    """Run one main-branch stage on a batched NCHW / (B, D) input."""
    spec = next(s for s in STAGES if s.name == name)
    p = params[name]
    if isinstance(spec, ConvSpec):
        return _apply_conv(p, spec, x, use_pallas)
    return _apply_fc(p, spec, x, use_pallas)


def apply_branch(params: dict, x: jax.Array, use_pallas: bool = False) -> jax.Array:
    """Side branch b1: (B, 64, 15, 15) activations -> (B, 2) logits."""
    x = _apply_conv(params[BRANCH_CONV.name], BRANCH_CONV, x, use_pallas)
    return _apply_fc(params[BRANCH_FC.name], BRANCH_FC, x, use_pallas)


def forward_main(params: dict, x: jax.Array, use_pallas: bool = False) -> jax.Array:
    """Full main-branch forward: (B, 3, 32, 32) -> (B, 2) logits."""
    for s in STAGES:
        x = apply_stage(params, s.name, x, use_pallas)
    return x


def forward_both(
    params: dict, x: jax.Array, use_pallas: bool = False
) -> tuple[jax.Array, jax.Array]:
    """(branch_logits, main_logits) — the joint-training forward."""
    h = x
    branch_logits = None
    for i, s in enumerate(STAGES, start=1):
        h = apply_stage(params, s.name, h, use_pallas)
        if i == BRANCH_AFTER:
            branch_logits = apply_branch(params, h, use_pallas)
    return branch_logits, h


def entropy(logits: jax.Array, use_pallas: bool = False) -> tuple[jax.Array, jax.Array]:
    """(probs, entropy-in-nats) for a batch of logits."""
    fn = pl_ent.softmax_entropy if use_pallas else ref.softmax_entropy
    return fn(logits)


def infer_early_exit(
    params: dict, x: jax.Array, threshold: float, use_pallas: bool = False
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Reference BranchyNet inference semantics (used by tests and fixtures).

    Returns (predictions, exited_mask, branch_entropy). A sample exits at
    b1 when its branch entropy < threshold; otherwise the main branch
    classifies it. (Batched: both paths are computed, the mask selects —
    the *serving* system in Rust actually skips the cloud stages.)
    """
    h = x
    for i, s in enumerate(STAGES, start=1):
        h = apply_stage(params, s.name, h, use_pallas)
        if i == BRANCH_AFTER:
            blog = apply_branch(params, h, use_pallas)
    _, ent = entropy(blog, use_pallas)
    exited = ent < threshold
    bpred = jnp.argmax(blog, axis=-1)
    mpred = jnp.argmax(h, axis=-1)
    return jnp.where(exited, bpred, mpred), exited, ent
