"""Procedural two-class image dataset + Gaussian blur (build-time only).

Substitution (DESIGN.md §4): the paper trains B-AlexNet on a cat-vs-dog
photo dataset [8] and probes Fig. 6 by applying Gaussian blur with kernel
sizes {5, 15, 65}. We have no photo corpus offline, so we synthesize a
binary texture-classification task with the same *relevant* property: the
two classes are separable through local texture statistics that Gaussian
blur progressively destroys, so side-branch confidence (and hence exit
probability) degrades monotonically with blur — the mechanism Fig. 6
demonstrates.

  class 0 ("cat"):  smooth low-frequency blobs (random Gaussian bumps)
  class 1 ("dog"):  oriented high-frequency stripes (random sinusoids)

Both get per-image random phase/scale/orientation, channel tinting and
additive noise so the task is non-trivial but learnable in a few hundred
CPU steps.
"""

from __future__ import annotations

import numpy as np

IMG = 32
CHANNELS = 3


def _coords() -> tuple[np.ndarray, np.ndarray]:
    g = np.arange(IMG, dtype=np.float32)
    return np.meshgrid(g, g, indexing="ij")


def _blobs(rng: np.random.Generator) -> np.ndarray:
    """Low-frequency class: sum of 3-6 random Gaussian bumps."""
    yy, xx = _coords()
    img = np.zeros((IMG, IMG), np.float32)
    for _ in range(rng.integers(3, 7)):
        cy, cx = rng.uniform(4, IMG - 4, size=2)
        sig = rng.uniform(3.0, 7.0)
        amp = rng.uniform(0.5, 1.0)
        img += amp * np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * sig**2))
    return img


def _stripes(rng: np.random.Generator) -> np.ndarray:
    """High-frequency class: oriented sinusoid grating."""
    yy, xx = _coords()
    theta = rng.uniform(0, np.pi)
    freq = rng.uniform(0.6, 1.4)  # cycles per ~2px: well above blob band
    phase = rng.uniform(0, 2 * np.pi)
    proj = np.cos(theta) * xx + np.sin(theta) * yy
    img = 0.5 + 0.5 * np.sin(freq * proj + phase)
    return img.astype(np.float32)


def make_dataset(
    n: int, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """n images, NCHW f32 in [0, 1]-ish (then standardized), labels {0,1}."""
    rng = np.random.default_rng(seed)
    xs = np.empty((n, CHANNELS, IMG, IMG), np.float32)
    ys = rng.integers(0, 2, size=n).astype(np.int32)
    for i in range(n):
        base = _stripes(rng) if ys[i] else _blobs(rng)
        # Cross-contaminate with a faint sample of the *other* class so the
        # decision boundary is non-trivial and confidence varies per image.
        other = _blobs(rng) if ys[i] else _stripes(rng)
        mix = rng.uniform(0.0, 0.35)
        base = (1 - mix) * base + mix * other
        tint = rng.uniform(0.6, 1.0, size=(CHANNELS, 1, 1)).astype(np.float32)
        noise = rng.normal(0, 0.12, size=(CHANNELS, IMG, IMG)).astype(np.float32)
        xs[i] = base[None, :, :] * tint + noise
    # Global standardization (train-time statistics are baked into the
    # exported artifacts via this same function, so edge and cloud agree).
    xs = (xs - 0.45) / 0.3
    return xs, ys


def gaussian_kernel1d(ksize: int) -> np.ndarray:
    """Normalized 1-D Gaussian taps; sigma follows the OpenCV convention
    ``sigma = 0.3*((ksize-1)*0.5 - 1) + 0.8`` used for `GaussianBlur`."""
    sigma = 0.3 * ((ksize - 1) * 0.5 - 1) + 0.8
    r = (ksize - 1) // 2
    t = np.arange(-r, r + 1, dtype=np.float32)
    k = np.exp(-(t**2) / (2 * sigma**2))
    return k / k.sum()


def gaussian_blur(x: np.ndarray, ksize: int) -> np.ndarray:
    """Separable Gaussian blur on NCHW images with reflect padding.

    ksize follows the paper's filter dimensions {5, 15, 65}; ksize <= 1 is
    the identity. Kernels larger than the image are allowed (the paper's 65
    on 32x32 images): reflect padding is applied repeatedly as needed.
    """
    if ksize <= 1:
        return x.copy()
    k = gaussian_kernel1d(ksize)
    r = (ksize - 1) // 2
    out = x.astype(np.float32)

    def pad_reflect(a: np.ndarray, axis: int, amount: int) -> np.ndarray:
        # np.pad reflect caps at len-1 per call; loop for huge kernels.
        while amount > 0:
            step = min(amount, a.shape[axis] - 1)
            width = [(0, 0)] * a.ndim
            width[axis] = (step, step)
            a = np.pad(a, width, mode="reflect")
            amount -= step
        return a

    # Convolve along H then W (separable).
    for axis in (2, 3):
        padded = pad_reflect(out, axis, r)
        acc = np.zeros_like(out)
        for i, tap in enumerate(k):
            sl = [slice(None)] * 4
            sl[axis] = slice(i, i + out.shape[axis])
            acc += tap * padded[tuple(sl)]
        out = acc
    return out


BLUR_LEVELS = {"none": 0, "low": 5, "mid": 15, "high": 65}
