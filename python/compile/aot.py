"""AOT export: B-AlexNet stages -> HLO-text artifacts + manifest + fixtures.

The interchange format is **HLO text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Exported per main-branch stage i (and for the side branch and the full
main-branch monolith), for every serving batch size in BATCH_SIZES and for
both kernel flavors:

    stage{i:02d}_{name}_{flavor}_b{B}.hlo.txt
        flavor 'pl'  — Pallas kernels (interpret=True), the paper-system
                       hot path expressed as L1 kernels;
        flavor 'ref' — the pure-jnp/XLA-fused equivalent. Same function
                       (kernel tests assert allclose); the Rust profiler
                       benchmarks both and serving config picks one.

Weights are baked into the artifacts as HLO constants, so the Rust
coordinator feeds activations only — there is no weight I/O on the request
path and no npz parsing in Rust.

Also written:
    manifest.json  — stage graph, shapes, alpha_i output bytes, FLOPs,
                     artifact paths, fixture index (parsed by the Rust
                     side's own JSON parser).
    fixtures/*.bin — raw little-endian f32 (C-order) input/expected-output
                     tensors for Rust runtime round-trip tests, plus the
                     Fig. 6 blurred batches.
"""

from __future__ import annotations

import argparse
import json
import math
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model, train
from .kernels import ref

BATCH_SIZES = (1, 4, 8)
FLAVORS = ("pl", "ref")
FIG6_BATCH = 48  # the paper applies "a batch with 48 samples" (§VI)
FIXTURE_SEED = 99


# ---------------------------------------------------------------------------
# HLO text lowering
# ---------------------------------------------------------------------------


def to_hlo_text(fn, *example_args) -> str:
    """Lower a jittable fn to HLO text via stablehlo -> XlaComputation."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants matters: the default HLO printer elides big
    # literals as `constant({...})`, which the Rust side's HLO text parser
    # silently reads back as zeros — the baked weights would vanish.
    return comp.as_hlo_text(print_large_constants=True)


def flops_of_stage(spec) -> int:
    """Analytic MAC-based FLOPs per sample for a stage (2 * MACs)."""
    if isinstance(spec, model.ConvSpec):
        # Output spatial dims pre-pool.
        shapes = dict(zip(model.STAGE_NAMES, model.stage_shapes()))
        # Recompute conv output (pre-pool) from the chain.
        c, h, w = model.INPUT_SHAPE
        for s in model.STAGES:
            if s.name == spec.name:
                oh = (h + 2 * s.padding - s.kernel) // s.stride + 1
                ow = (w + 2 * s.padding - s.kernel) // s.stride + 1
                return 2 * spec.in_ch * spec.kernel**2 * oh * ow * spec.out_ch
            if isinstance(s, model.ConvSpec):
                h, w = model._conv_out_hw(h, w, s)
                c = s.out_ch
        raise KeyError(spec.name)
    return 2 * spec.in_dim * spec.out_dim


def branch_flops() -> int:
    bc, bfc = model.BRANCH_CONV, model.BRANCH_FC
    h = w = model.branch_input_shape()[1]
    oh = (h + 2 * bc.padding - bc.kernel) // bc.stride + 1
    conv = 2 * bc.in_ch * bc.kernel**2 * oh * oh * bc.out_ch
    return conv + 2 * bfc.in_dim * bfc.out_dim


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------


def _write_bin(path: Path, arr: np.ndarray) -> dict:
    arr = np.ascontiguousarray(arr, dtype=np.float32)
    path.write_bytes(arr.tobytes())
    return {"path": path.name, "shape": list(arr.shape), "dtype": "f32"}


def export(out_dir: Path) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    fix_dir = out_dir / "fixtures"
    fix_dir.mkdir(exist_ok=True)
    params = train.load_weights(out_dir / "weights.npz")

    shapes = model.stage_shapes()
    stages_meta = []
    in_shape = model.INPUT_SHAPE
    for i, (spec, out_shape) in enumerate(zip(model.STAGES, shapes), start=1):
        artifacts: dict[str, dict[str, str]] = {f: {} for f in FLAVORS}
        for flavor in FLAVORS:
            use_pallas = flavor == "pl"
            fn = lambda x, _n=spec.name, _p=use_pallas: model.apply_stage(
                params, _n, x, use_pallas=_p
            )
            for bs in BATCH_SIZES:
                arg = jax.ShapeDtypeStruct((bs, *in_shape), jnp.float32)
                text = to_hlo_text(fn, arg)
                name = f"stage{i:02d}_{spec.name}_{flavor}_b{bs}.hlo.txt"
                (out_dir / name).write_text(text)
                artifacts[flavor][str(bs)] = name
        stages_meta.append(
            {
                "index": i,
                "name": spec.name,
                "kind": "conv" if isinstance(spec, model.ConvSpec) else "fc",
                "in_shape": list(in_shape),
                "out_shape": list(out_shape),
                "out_bytes_per_sample": model.output_bytes(out_shape),
                "flops_per_sample": flops_of_stage(spec),
                "artifacts": artifacts,
            }
        )
        print(f"exported stage {i} ({spec.name}) in={in_shape} out={out_shape}")
        in_shape = out_shape

    # Side branch: activations -> (probs, entropy). The exit statistic is
    # fused into the artifact so the edge node gets the gate in one call.
    def branch_fn_pl(x):
        logits = model.apply_branch(params, x, use_pallas=True)
        return model.entropy(logits, use_pallas=True)

    def branch_fn_ref(x):
        logits = model.apply_branch(params, x, use_pallas=False)
        return model.entropy(logits, use_pallas=False)

    branch_meta = {
        "after_stage": model.BRANCH_AFTER,
        "name": "b1",
        "in_shape": list(model.branch_input_shape()),
        "num_classes": model.NUM_CLASSES,
        "flops_per_sample": branch_flops(),
        "artifacts": {f: {} for f in FLAVORS},
    }
    for flavor, fn in (("pl", branch_fn_pl), ("ref", branch_fn_ref)):
        for bs in BATCH_SIZES:
            arg = jax.ShapeDtypeStruct((bs, *model.branch_input_shape()), jnp.float32)
            name = f"branch_b1_{flavor}_b{bs}.hlo.txt"
            (out_dir / name).write_text(to_hlo_text(fn, arg))
            branch_meta["artifacts"][flavor][str(bs)] = name
    print("exported branch b1")

    # Full main-branch monolith (cloud-only single executable + the L2
    # fusion ablation target).
    full_meta = {"artifacts": {f: {} for f in FLAVORS}}
    for flavor in FLAVORS:
        fn = lambda x, _p=(flavor == "pl"): model.forward_main(
            params, x, use_pallas=_p
        )
        for bs in BATCH_SIZES:
            arg = jax.ShapeDtypeStruct((bs, *model.INPUT_SHAPE), jnp.float32)
            name = f"full_main_{flavor}_b{bs}.hlo.txt"
            (out_dir / name).write_text(to_hlo_text(fn, arg))
            full_meta["artifacts"][flavor][str(bs)] = name
    print("exported full main branch")

    # ----------------------------------------------------------------- #
    # Fixtures
    # ----------------------------------------------------------------- #
    fixtures: dict = {}
    rng_x, rng_y = data.make_dataset(8, seed=FIXTURE_SEED)
    fixtures["input_b8"] = _write_bin(fix_dir / "input_b8.bin", rng_x)
    fixtures["labels_b8"] = {
        "path": "labels_b8.json",
        "values": [int(v) for v in rng_y],
    }
    (fix_dir / "labels_b8.json").write_text(json.dumps(fixtures["labels_b8"]["values"]))

    # Expected per-stage outputs (ref flavor) for the runtime round-trip.
    h = jnp.asarray(rng_x)
    for i, spec in enumerate(model.STAGES, start=1):
        h = model.apply_stage(params, spec.name, h, use_pallas=False)
        fixtures[f"expected_stage{i:02d}_b8"] = _write_bin(
            fix_dir / f"expected_stage{i:02d}_b8.bin", np.asarray(h)
        )
        if i == model.BRANCH_AFTER:
            probs, ent = model.entropy(
                model.apply_branch(params, h, use_pallas=False)
            )
            fixtures["expected_branch_probs_b8"] = _write_bin(
                fix_dir / "expected_branch_probs_b8.bin", np.asarray(probs)
            )
            fixtures["expected_branch_entropy_b8"] = _write_bin(
                fix_dir / "expected_branch_entropy_b8.bin", np.asarray(ent)
            )

    # Fig. 6 batches: 48 fresh samples per blur level.
    xs, ys = data.make_dataset(FIG6_BATCH, seed=FIXTURE_SEED + 1)
    fig6 = {}
    for level, ksize in data.BLUR_LEVELS.items():
        xb = data.gaussian_blur(xs, ksize)
        fig6[level] = _write_bin(fix_dir / f"fig6_{level}_b48.bin", xb)
        fig6[level]["blur_ksize"] = ksize
    fixtures["fig6"] = fig6
    fixtures["fig6_labels"] = [int(v) for v in ys]
    print("wrote fixtures")

    manifest = {
        "model": "b-alexnet",
        "paper": "Pacheco & Couto, ISCC 2020 (BranchyNet partitioning)",
        "num_classes": model.NUM_CLASSES,
        "input_shape": list(model.INPUT_SHAPE),
        "input_bytes_per_sample": model.output_bytes(model.INPUT_SHAPE),
        "batch_sizes": list(BATCH_SIZES),
        "flavors": list(FLAVORS),
        "entropy_max_nats": math.log(model.NUM_CLASSES),
        "stages": stages_meta,
        "branch": branch_meta,
        "full": full_meta,
        "fixtures": fixtures,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote manifest with {len(stages_meta)} stages -> {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", type=Path, default=Path("../artifacts"))
    args = ap.parse_args()
    export(args.out)


if __name__ == "__main__":
    main()
