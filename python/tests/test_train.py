"""Training sanity: joint loss decreases, weights round-trip, exits degrade
with blur (the trained-model precondition for Fig. 6)."""

from __future__ import annotations

import math
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model, train

ART = Path(__file__).resolve().parents[2] / "artifacts"


def test_cross_entropy_label_smoothing():
    """Perfect prediction still pays the smoothing floor (> 0)."""
    logits = jnp.asarray([[50.0, -50.0]])
    labels = jnp.asarray([0])
    loss = train.cross_entropy(logits, labels)
    assert float(loss) > 0.0
    # And the floor is exactly the smoothed-target entropy term.
    assert float(loss) == pytest.approx(train.LABEL_SMOOTH / 2 * 100.0, rel=1e-3)


def test_short_training_reduces_loss(tmp_path):
    """A 30-step run must cut the joint loss by >50% on this easy task."""
    params = train.train(tmp_path, steps=30, seed=123)
    log = __import__("json").loads((tmp_path / "training_log.json").read_text())
    hist = log["history"]
    assert hist[-1]["loss"] < 0.5 * hist[0]["loss"]
    assert (tmp_path / "weights.npz").exists()


def test_weights_roundtrip(tmp_path):
    params = model.init_params(jax.random.PRNGKey(9))
    flat = train.flatten_params(params)
    np.savez(tmp_path / "w.npz", **flat)
    loaded = train.load_weights(tmp_path / "w.npz")
    for stage in params:
        for leaf in params[stage]:
            np.testing.assert_array_equal(params[stage][leaf], loaded[stage][leaf])


@pytest.mark.skipif(not (ART / "weights.npz").exists(), reason="artifacts not built")
def test_trained_model_accuracy():
    """The shipped weights must actually classify held-out data."""
    params = train.load_weights(ART / "weights.npz")
    xs, ys = data.make_dataset(256, seed=1234)
    _, ml = model.forward_both(params, jnp.asarray(xs))
    acc = float(jnp.mean((jnp.argmax(ml, -1) == jnp.asarray(ys)).astype(jnp.float32)))
    assert acc > 0.9, f"main-branch accuracy {acc}"


@pytest.mark.skipif(not (ART / "weights.npz").exists(), reason="artifacts not built")
def test_blur_degrades_branch_confidence():
    """Fig. 6 precondition: mean branch entropy rises with blur level."""
    params = train.load_weights(ART / "weights.npz")
    xs, _ = data.make_dataset(48, seed=77)
    ents = []
    for k in (0, 5, 15, 65):
        xb = jnp.asarray(data.gaussian_blur(xs, k))
        _, _, ent = model.infer_early_exit(params, xb, threshold=0.3)
        ents.append(float(ent.mean()))
    assert ents[0] < ents[1] < ents[3], ents
    assert ents[0] < ents[2] < ents[3] + 1e-6, ents


@pytest.mark.skipif(not (ART / "weights.npz").exists(), reason="artifacts not built")
def test_exit_probability_monotone_in_threshold_trained():
    """P[exit] as a function of threshold is a CDF — nondecreasing 0 -> 1."""
    params = train.load_weights(ART / "weights.npz")
    xs, _ = data.make_dataset(48, seed=78)
    x = jnp.asarray(xs)
    fracs = []
    for thr in np.linspace(0.0, math.log(2), 8):
        _, exited, _ = model.infer_early_exit(params, x, float(thr))
        fracs.append(float(exited.mean()))
    assert all(b >= a - 1e-9 for a, b in zip(fracs, fracs[1:]))
    assert fracs[0] == 0.0
