"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps).

This is the CORE correctness signal for the exported artifacts: the 'pl'
flavor HLO is lowered from exactly these kernel implementations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, matmul, maxpool, ref, softmax_entropy

jax.config.update("jax_platform_name", "cpu")

RTOL, ATOL = 1e-4, 1e-4


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_bias_act_matches_ref(m, k, n, act, seed):
    kx, ky, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (m, k))
    y = jax.random.normal(ky, (k, n))
    b = jax.random.normal(kb, (n,))
    got = matmul.matmul_bias_act(x, y, b, act=act)
    want = ref.matmul_bias_act(x, y, b, act=act)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 130, 140), (1, 1, 1)])
def test_matmul_block_boundaries(shape):
    """Exact block multiples and oddballs around the 128 MXU tile."""
    m, k, n = shape
    x, y = _rand(0, (m, k)), _rand(1, (k, n))
    b = _rand(2, (n,))
    np.testing.assert_allclose(
        matmul.matmul_bias_act(x, y, b),
        ref.matmul_bias_act(x, y, b),
        rtol=5e-4,
        atol=5e-4,
    )


@pytest.mark.parametrize("blocks", [(32, 32, 32), (64, 128, 16), (8, 8, 8)])
def test_matmul_block_shape_invariance(blocks):
    """The result must not depend on the chosen tiling."""
    bm, bn, bk = blocks
    x, y, b = _rand(3, (100, 60)), _rand(4, (60, 44)), _rand(5, (44,))
    got = matmul.matmul_bias_act(x, y, b, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(got, ref.matmul_bias_act(x, y, b), rtol=RTOL, atol=ATOL)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul.matmul_bias_act(_rand(0, (3, 4)), _rand(1, (5, 6)), _rand(2, (6,)))
    with pytest.raises(ValueError):
        matmul.matmul_bias_act(_rand(0, (3, 4)), _rand(1, (4, 6)), _rand(2, (7,)))
    with pytest.raises(ValueError):
        matmul.matmul_bias_act(
            _rand(0, (3, 4)), _rand(1, (4, 6)), _rand(2, (6,)), act="gelu"
        )


def test_matmul_zero_k_padding_exact():
    """K-padding with zeros must not perturb the contraction."""
    x, y, b = _rand(6, (5, 3)), _rand(7, (3, 5)), jnp.zeros((5,))
    got = matmul.matmul_bias_act(x, y, b, block_k=128)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=RTOL, atol=ATOL)


def test_vmem_budget():
    """Default tiling must fit a 16 MiB VMEM core with headroom."""
    assert matmul.vmem_bytes(128, 128, 128) < 16 * 2**20 / 4


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 4),
    c=st.integers(1, 8),
    o=st.integers(1, 8),
    hw=st.integers(5, 20),
    kern=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    pad=st.integers(0, 2),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(n, c, o, hw, kern, stride, pad, act, seed):
    if hw + 2 * pad < kern:
        return
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k0, (n, c, hw, hw))
    w = jax.random.normal(k1, (o, c, kern, kern))
    b = jax.random.normal(k2, (o,))
    got = conv2d.conv2d(x, w, b, stride=stride, padding=pad, act=act)
    want = ref.conv2d(x, w, b, stride=stride, padding=pad, act=act)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_conv2d_channel_mismatch():
    with pytest.raises(ValueError):
        conv2d.conv2d(_rand(0, (1, 3, 8, 8)), _rand(1, (4, 5, 3, 3)), _rand(2, (4,)))


def test_conv2d_alexnet_shapes():
    """The exact stage-1 and stage-3 geometries used in B-AlexNet."""
    x = _rand(0, (2, 3, 32, 32))
    w = _rand(1, (64, 3, 5, 5), 0.1)
    b = _rand(2, (64,))
    got = conv2d.conv2d(x, w, b, stride=1, padding=2, act="relu")
    assert got.shape == (2, 64, 32, 32)
    np.testing.assert_allclose(
        got, ref.conv2d(x, w, b, 1, 2, "relu"), rtol=RTOL, atol=ATOL
    )


def test_im2col_identity_kernel():
    """1x1 im2col is just a transpose-reshape."""
    x = _rand(3, (2, 4, 6, 6))
    cols = ref.im2col(x, 1, 1, 1, 0)
    assert cols.shape == (2 * 6 * 6, 4)
    np.testing.assert_allclose(
        cols.reshape(2, 6, 6, 4), jnp.transpose(x, (0, 2, 3, 1)), rtol=1e-6
    )


# ---------------------------------------------------------------------------
# maxpool
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 4),
    c=st.integers(1, 40),
    hw=st.integers(3, 24),
    window=st.sampled_from([2, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(n, c, hw, window, stride, seed):
    if hw < window:
        return
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, c, hw, hw))
    got = maxpool.maxpool2d(x, window, stride)
    want = ref.maxpool2d(x, window, stride)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_maxpool_channel_block_padding():
    """Channel counts straddling the block size must slice cleanly."""
    for c in (31, 32, 33, 65):
        x = _rand(c, (1, c, 9, 9))
        np.testing.assert_allclose(
            maxpool.maxpool2d(x, 3, 2, block_c=32), ref.maxpool2d(x, 3, 2), rtol=1e-6
        )


def test_maxpool_rejects_small_input():
    with pytest.raises(ValueError):
        maxpool.maxpool2d(_rand(0, (1, 1, 2, 2)), window=3)


def test_maxpool_is_max():
    """Every output element equals the max of its window (brute check)."""
    x = np.asarray(_rand(9, (1, 2, 7, 7)))
    got = np.asarray(maxpool.maxpool2d(jnp.asarray(x), 3, 2))
    for ch in range(2):
        for i in range(got.shape[2]):
            for j in range(got.shape[3]):
                win = x[0, ch, 2 * i : 2 * i + 3, 2 * j : 2 * j + 3]
                assert got[0, ch, i, j] == pytest.approx(win.max(), rel=1e-6)


# ---------------------------------------------------------------------------
# softmax + entropy
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 200),
    c=st.integers(2, 10),
    scale=st.floats(0.1, 50.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_entropy_matches_ref(b, c, scale, seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (b, c)) * scale
    p, h = softmax_entropy.softmax_entropy(logits)
    pr, hr = ref.softmax_entropy(logits)
    np.testing.assert_allclose(p, pr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h, hr, rtol=1e-5, atol=1e-6)


def test_entropy_bounds_and_extremes():
    """H in [0, ln C]; uniform hits the top, one-hot-ish hits ~0."""
    c = 4
    uniform = jnp.zeros((1, c))
    _, h_uni = softmax_entropy.softmax_entropy(uniform)
    np.testing.assert_allclose(h_uni, [np.log(c)], rtol=1e-6)

    peaked = jnp.asarray([[100.0, 0.0, 0.0, 0.0]])
    p, h_pk = softmax_entropy.softmax_entropy(peaked)
    assert float(h_pk[0]) < 1e-6
    assert float(p[0, 0]) > 0.999

    rand = _rand(1, (64, c), 3.0)
    _, h = softmax_entropy.softmax_entropy(rand)
    assert np.all(np.asarray(h) >= -1e-6)
    assert np.all(np.asarray(h) <= np.log(c) + 1e-6)


def test_softmax_rows_sum_to_one():
    p, _ = softmax_entropy.softmax_entropy(_rand(2, (300, 5), 10.0))
    np.testing.assert_allclose(np.asarray(p).sum(axis=1), np.ones(300), rtol=1e-5)


def test_entropy_extreme_logits_stable():
    """No overflow/NaN for huge logit magnitudes."""
    logits = jnp.asarray([[1e4, -1e4], [-1e4, 1e4], [1e4, 1e4]])
    p, h = softmax_entropy.softmax_entropy(logits)
    assert np.all(np.isfinite(np.asarray(p)))
    assert np.all(np.isfinite(np.asarray(h)))
    np.testing.assert_allclose(h[2], np.log(2), rtol=1e-5)
