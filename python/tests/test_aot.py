"""AOT export consistency: manifest <-> artifacts <-> model declarations.

Requires `make artifacts` to have run (skipped otherwise) — these validate
the actual shipped artifacts, not a rebuild.
"""

from __future__ import annotations

import json
import math
from pathlib import Path

import numpy as np
import pytest

from compile import aot, model

ART = Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(), reason="artifacts not built"
)


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_manifest_stage_chain(manifest):
    stages = manifest["stages"]
    assert [s["name"] for s in stages] == list(model.STAGE_NAMES)
    # Chain property: each stage's in_shape is the predecessor's out_shape.
    prev = manifest["input_shape"]
    for s in stages:
        assert s["in_shape"] == prev
        prev = s["out_shape"]


def test_manifest_alpha_bytes(manifest):
    for s, shape in zip(manifest["stages"], model.stage_shapes()):
        assert s["out_bytes_per_sample"] == 4 * int(np.prod(shape))
    assert manifest["input_bytes_per_sample"] == 4 * int(
        np.prod(model.INPUT_SHAPE)
    )


def test_manifest_entropy_max(manifest):
    assert manifest["entropy_max_nats"] == pytest.approx(math.log(2))


def test_all_artifacts_exist_and_parse(manifest):
    """Every referenced HLO file exists, is non-trivial, and has an ENTRY."""
    refs = []
    for s in manifest["stages"]:
        for flavor in manifest["flavors"]:
            refs += list(s["artifacts"][flavor].values())
    for flavor in manifest["flavors"]:
        refs += list(manifest["branch"]["artifacts"][flavor].values())
        refs += list(manifest["full"]["artifacts"][flavor].values())
    assert len(refs) == (8 + 1 + 1) * 2 * len(manifest["batch_sizes"])
    for r in refs:
        text = (ART / r).read_text()
        assert "ENTRY" in text, r
        assert "custom-call" not in text, f"{r} contains a custom-call"
        # Regression: the default HLO printer elides big literals as
        # `constant({...})`; the Rust text parser reads those as ZEROS and
        # the model silently degenerates (all-ln2 entropies, Fig. 6 flat).
        assert "constant({...})" not in text, f"{r} has elided constants"


def test_batch_sizes_parametrize_entry_shapes(manifest):
    """stage1's b1/b8 artifacts must declare different leading dims."""
    s1 = manifest["stages"][0]
    t1 = (ART / s1["artifacts"]["ref"]["1"]).read_text()
    t8 = (ART / s1["artifacts"]["ref"]["8"]).read_text()
    assert "f32[1,3,32,32]" in t1
    assert "f32[8,3,32,32]" in t8


def test_fixture_files_match_declared_shapes(manifest):
    fx = manifest["fixtures"]
    for key, meta in fx.items():
        if not isinstance(meta, dict) or "shape" not in meta:
            continue
        path = ART / "fixtures" / meta["path"]
        n_items = int(np.prod(meta["shape"]))
        assert path.stat().st_size == 4 * n_items, key


def test_fig6_fixtures_cover_blur_levels(manifest):
    fig6 = manifest["fixtures"]["fig6"]
    assert set(fig6) == {"none", "low", "mid", "high"}
    for meta in fig6.values():
        assert meta["shape"] == [48, 3, 32, 32]
    assert len(manifest["fixtures"]["fig6_labels"]) == 48


def test_expected_stage_fixtures_chain(manifest):
    """Expected outputs exist for all 8 stages + branch probs/entropy."""
    fx = manifest["fixtures"]
    for i in range(1, 9):
        assert f"expected_stage{i:02d}_b8" in fx
    assert "expected_branch_probs_b8" in fx
    assert "expected_branch_entropy_b8" in fx
    ent = np.fromfile(
        ART / "fixtures" / fx["expected_branch_entropy_b8"]["path"], dtype=np.float32
    )
    assert ent.shape == (8,)
    assert np.all(ent >= 0) and np.all(ent <= math.log(2) + 1e-5)


def test_flops_positive_and_ordered(manifest):
    flops = [s["flops_per_sample"] for s in manifest["stages"]]
    assert all(f > 0 for f in flops)
    # conv2 is the FLOPs-heaviest stage in this geometry.
    assert max(flops) == flops[1]
