"""Dataset + blur tests: class separability and the Fig. 6 blur mechanism."""

from __future__ import annotations

import numpy as np
import pytest

from compile import data


def test_dataset_shapes_and_determinism():
    x1, y1 = data.make_dataset(16, seed=3)
    x2, y2 = data.make_dataset(16, seed=3)
    assert x1.shape == (16, 3, 32, 32) and x1.dtype == np.float32
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = data.make_dataset(16, seed=4)
    assert not np.array_equal(x1, x3)


def test_both_classes_present():
    _, y = data.make_dataset(64, seed=0)
    assert set(np.unique(y)) == {0, 1}


def test_classes_differ_in_frequency_content():
    """Stripes (class 1) must carry more high-frequency energy than blobs."""
    x, y = data.make_dataset(128, seed=5)
    gray = x.mean(axis=1)
    # High-frequency proxy: mean squared horizontal+vertical gradient.
    def hf(imgs):
        gx = np.diff(imgs, axis=-1) ** 2
        gy = np.diff(imgs, axis=-2) ** 2
        return gx.mean(axis=(-1, -2)) + gy.mean(axis=(-1, -2))

    e = hf(gray)
    assert e[y == 1].mean() > 2.0 * e[y == 0].mean()


def test_gaussian_kernel_normalized():
    for k in (3, 5, 15, 65):
        taps = data.gaussian_kernel1d(k)
        assert taps.shape == (k,)
        np.testing.assert_allclose(taps.sum(), 1.0, rtol=1e-6)
        assert np.all(taps > 0)
        # symmetric
        np.testing.assert_allclose(taps, taps[::-1], rtol=1e-6)


def test_blur_identity_below_threshold():
    x, _ = data.make_dataset(4, seed=1)
    np.testing.assert_array_equal(data.gaussian_blur(x, 0), x)
    np.testing.assert_array_equal(data.gaussian_blur(x, 1), x)


def test_blur_reduces_variance_monotonically():
    """The paper's blur levels {5,15,65} must progressively smooth."""
    x, _ = data.make_dataset(8, seed=2)
    variances = [data.gaussian_blur(x, k).var() for k in (0, 5, 15, 65)]
    assert variances[0] > variances[1] > variances[2] > variances[3]


def test_blur_preserves_mean():
    """A normalized blur is (approximately) mean-preserving."""
    x, _ = data.make_dataset(4, seed=6)
    b = data.gaussian_blur(x, 15)
    np.testing.assert_allclose(b.mean(), x.mean(), atol=0.02)


def test_blur_kernel_larger_than_image():
    """ksize=65 on 32x32 images (the paper's 'high distortion') must work."""
    x, _ = data.make_dataset(2, seed=7)
    b = data.gaussian_blur(x, 65)
    assert b.shape == x.shape
    assert np.all(np.isfinite(b))
    # Heavy blur approaches a constant image.
    assert b.var() < 0.15 * x.var()


def test_blur_levels_cover_paper():
    assert data.BLUR_LEVELS == {"none": 0, "low": 5, "mid": 15, "high": 65}
