"""L2 tests: B-AlexNet topology, shapes, early-exit semantics, pallas/ref parity."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    return jax.random.normal(jax.random.PRNGKey(1), (4, *model.INPUT_SHAPE))


def test_stage_shapes_chain():
    """Declared stage shapes must match actual forward shapes."""
    shapes = model.stage_shapes()
    assert shapes == [
        (64, 15, 15),
        (96, 7, 7),
        (128, 7, 7),
        (128, 7, 7),
        (96, 3, 3),
        (256,),
        (128,),
        (2,),
    ]


def test_forward_shapes_match_declared(params, batch):
    h = batch
    for spec, want in zip(model.STAGES, model.stage_shapes()):
        h = model.apply_stage(params, spec.name, h)
        assert h.shape == (4, *want), spec.name


def test_alpha_profile_non_monotonic():
    """conv1's output is larger than the raw input — the property that
    makes naive 'split as early as possible' suboptimal (paper §IV-C)."""
    sizes = [model.output_bytes(s) for s in model.stage_shapes()]
    input_bytes = model.output_bytes(model.INPUT_SHAPE)
    assert sizes[0] > input_bytes
    assert sizes[-1] < input_bytes
    assert any(sizes[i] < sizes[i + 1] for i in range(len(sizes) - 1))


def test_branch_consumes_stage1(params, batch):
    h = model.apply_stage(params, "conv1", batch)
    logits = model.apply_branch(params, h)
    assert logits.shape == (4, model.NUM_CLASSES)


def test_forward_both_consistent_with_main(params, batch):
    bl, ml = model.forward_both(params, batch)
    ml2 = model.forward_main(params, batch)
    np.testing.assert_allclose(ml, ml2, rtol=1e-6)
    assert bl.shape == ml.shape


def test_pallas_and_ref_paths_agree(params, batch):
    """The exported 'pl' artifacts compute the same function as 'ref'."""
    bl_r, ml_r = model.forward_both(params, batch, use_pallas=False)
    bl_p, ml_p = model.forward_both(params, batch, use_pallas=True)
    np.testing.assert_allclose(bl_p, bl_r, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(ml_p, ml_r, rtol=1e-3, atol=1e-4)


def test_early_exit_threshold_monotone(params, batch):
    """Raising the threshold can only exit MORE samples."""
    prev = 0.0
    for thr in (0.0, 0.1, 0.3, 0.5, math.log(2)):
        _, exited, _ = model.infer_early_exit(params, batch, thr)
        frac = float(exited.mean())
        assert frac >= prev - 1e-9
        prev = frac


def test_early_exit_extremes(params, batch):
    """thr=0 exits nothing; thr=ln(2)+eps exits everything (2 classes)."""
    _, exited0, _ = model.infer_early_exit(params, batch, 0.0)
    assert not bool(exited0.any())
    _, exited1, _ = model.infer_early_exit(params, batch, math.log(2) + 1e-3)
    assert bool(exited1.all())


def test_exit_prediction_source(params, batch):
    """Exited samples use branch argmax; others use main argmax."""
    h = model.apply_stage(params, "conv1", batch)
    blog = model.apply_branch(params, h)
    mlog = model.forward_main(params, batch)
    pred, exited, _ = model.infer_early_exit(params, batch, 0.4)
    bpred = jnp.argmax(blog, -1)
    mpred = jnp.argmax(mlog, -1)
    for i in range(batch.shape[0]):
        want = bpred[i] if bool(exited[i]) else mpred[i]
        assert int(pred[i]) == int(want)


def test_param_count_structure(params):
    """Every stage + the branch has w and b."""
    names = set(params.keys())
    assert names == set(model.STAGE_NAMES) | {"b1_conv", "b1_fc"}
    assert model.param_count(params) > 500_000  # AlexNet-scale, not a toy


def test_init_deterministic():
    a = model.init_params(jax.random.PRNGKey(42))
    b = model.init_params(jax.random.PRNGKey(42))
    for k in a:
        np.testing.assert_array_equal(a[k]["w"], b[k]["w"])
