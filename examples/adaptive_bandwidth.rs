//! Adaptive re-planning under a time-varying uplink — the scenario
//! Neurosurgeon [3] motivates and the paper's model enables: as the
//! bandwidth trace moves between 3G-like and Wi-Fi-like regimes, the
//! [`branchyserve::planner::AdaptivePlanner`] re-solves the partitioning
//! problem against its precomputed prefix-sum state (cached by
//! log-bucketed bandwidth, with hysteresis against flapping) and swaps
//! the coordinator's active plan live — no restart, in-flight batches
//! finish on the old plan, and every applied switch is counted in the
//! coordinator metrics.
//!
//!     cargo run --release --example adaptive_bandwidth

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use branchyserve::config::settings::Flavor;
use branchyserve::coordinator::{Coordinator, CoordinatorConfig};
use branchyserve::model::Manifest;
use branchyserve::network::bandwidth::LinkModel;
use branchyserve::network::{BandwidthTrace, Channel};
use branchyserve::planner::{AdaptiveConfig, AdaptivePlanner, Planner};
use branchyserve::profiler::{self, ProfileOptions, ProfileReport};
use branchyserve::runtime::InferenceEngine;
use branchyserve::util::timefmt::format_secs;
use branchyserve::workload::{LoadGen, LoadReport};

const GAMMA: f64 = 20.0;
const EXIT_P: f64 = 0.5;
const PHASE: Duration = Duration::from_secs(4);

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let dir = Path::new("artifacts");
    let manifest = Manifest::load(dir)?;
    let edge = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "edge")?;
    let cloud = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "cloud")?;
    edge.warmup()?;
    cloud.warmup()?;

    let report: ProfileReport = profiler::measure(&edge, ProfileOptions::default())?;
    let delay = report.to_delay_profile(GAMMA);
    let desc = manifest.to_desc(EXIT_P);

    // Bandwidth trace: Wi-Fi -> 3G -> 4G, one phase each.
    let trace = BandwidthTrace::new(vec![
        (0.0, 18.80),
        (PHASE.as_secs_f64(), 1.10),
        (2.0 * PHASE.as_secs_f64(), 5.85),
    ])?;
    let channel = Arc::new(Channel::new(trace.clone(), 0.0, 0.0, 3));

    // One planner owns all link-independent state; the initial solve and
    // every replan below are O(N) sweeps against it.
    let planner = Planner::new(&desc, &delay, 1e-9, false);
    let initial_link = LinkModel::new(trace.mbps_at(0.0), 0.0);
    let initial = planner.plan_for(initial_link);
    println!(
        "initial plan @ {:.2} Mbps: split after '{}' (E[T] {})",
        trace.mbps_at(0.0),
        initial.split_label(&desc),
        format_secs(initial.expected_time_s)
    );

    let coordinator = Arc::new(Coordinator::start(
        edge,
        cloud,
        channel,
        initial,
        CoordinatorConfig {
            entropy_threshold: 0.4,
            ..Default::default()
        },
    ));

    // Replan loop: every 500 ms, observe the channel's current bandwidth,
    // solve through the plan cache, and swap the plan when the hysteresis
    // test accepts the new split.
    let replanner = AdaptivePlanner::spawn(
        planner,
        coordinator.clone(),
        AdaptiveConfig {
            interval: Duration::from_millis(500),
            ..Default::default()
        },
    );

    // Load through all three phases.
    let t0 = Instant::now();
    let gen = LoadGen {
        rate_rps: 20.0,
        duration: 3 * PHASE,
        seed: 11,
    };
    let report: LoadReport = gen.run(&coordinator);
    println!(
        "\nran {:.1}s: {} completed, exit rate {:.1}%, accuracy {:.1}%, \
         mean latency {}, p95 {}",
        t0.elapsed().as_secs_f64(),
        report.completed,
        report.exit_rate() * 100.0,
        report.accuracy() * 100.0,
        format_secs(report.mean_latency()),
        format_secs(report.p(95.0)),
    );

    let stats = replanner.stop();
    println!(
        "replanner: {} observations, {} plan switches, plan cache {} hits / {} misses",
        stats.replans, stats.switches, stats.cache_hits, stats.cache_misses
    );
    println!("final metrics: {}", coordinator.metrics().summary());
    Ok(())
}
