//! Fig. 6 in serving mode: degrade image quality with Gaussian blur *in
//! the Rust workload path* and watch the side-branch exit rate (and thus
//! the effective serving latency) respond — image quality is a runtime
//! property the partition planner should track, which is the paper's
//! closing argument (§VI last paragraph + §VII).
//!
//!     cargo run --release --example image_quality

use std::path::Path;
use std::sync::Arc;

use branchyserve::config::settings::Flavor;
use branchyserve::coordinator::{Coordinator, CoordinatorConfig};
use branchyserve::harness::Table;
use branchyserve::model::Manifest;
use branchyserve::network::bandwidth::{LinkModel, Profile};
use branchyserve::network::Channel;
use branchyserve::partition::solver;
use branchyserve::profiler::{self, ProfileOptions};
use branchyserve::runtime::InferenceEngine;
use branchyserve::util::timefmt::format_secs;
use branchyserve::workload::blur::gaussian_blur;
use branchyserve::workload::ImageSource;

const BLUR_LEVELS: [(&str, usize); 4] = [("none", 0), ("low", 5), ("mid", 15), ("high", 65)];
const BATCH: usize = 48; // the paper's Fig. 6 batch size
const THRESHOLD: f32 = 0.4;

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let dir = Path::new("artifacts");
    let manifest = Manifest::load(dir)?;
    let edge = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "edge")?;
    let cloud = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "cloud")?;

    edge.warmup()?;
    cloud.warmup()?;
    let profile = profiler::measure(&edge, ProfileOptions::default())?;
    let link = LinkModel::from_profile(Profile::FourG);
    let desc = manifest.to_desc(0.5);
    let solved = solver::solve(&desc, &profile.to_delay_profile(20.0), link, 1e-9, false);
    println!(
        "solver would pick '{}'; pinning the split after stage 2 so the \
         branch is active and the quality -> exit -> latency chain is visible",
        solved.split_label(&desc)
    );
    let plan = branchyserve::partition::PartitionPlan::from_split(
        2,
        solved.expected_time_s,
        branchyserve::config::settings::Strategy::ShortestPath,
        &desc,
    );

    let coordinator = Coordinator::start(
        edge,
        cloud,
        Arc::new(Channel::from_link(link)),
        plan,
        CoordinatorConfig {
            entropy_threshold: THRESHOLD,
            ..Default::default()
        },
    );

    let mut table = Table::new(&[
        "blur", "ksize", "exit rate", "accuracy", "mean latency", "p95 latency",
    ]);
    for (name, ksize) in BLUR_LEVELS {
        let mut source = ImageSource::new(42);
        let (images, labels) = source.batch(BATCH);
        let mut latencies = Vec::with_capacity(BATCH);
        let mut exits = 0usize;
        let mut correct = 0usize;
        // Submit asynchronously so the batcher actually forms batches.
        let mut rx_and_label = Vec::with_capacity(BATCH);
        for (img, label) in images.iter().zip(&labels) {
            let blurred = gaussian_blur(img, ksize);
            let (_, rx) = coordinator.submit(blurred)?;
            rx_and_label.push((rx, *label));
        }
        for (rx, label) in rx_and_label {
            let resp = rx.recv()?;
            latencies.push(resp.latency_s);
            if resp.exited_early() {
                exits += 1;
            }
            if resp.class == label {
                correct += 1;
            }
        }
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = latencies.iter().sum::<f64>() / latencies.len() as f64;
        let p95 = latencies[(latencies.len() as f64 * 0.95) as usize - 1];
        table.row(vec![
            name.to_string(),
            ksize.to_string(),
            format!("{:.1}%", 100.0 * exits as f64 / BATCH as f64),
            format!("{:.1}%", 100.0 * correct as f64 / BATCH as f64),
            format_secs(mean),
            format_secs(p95),
        ]);
    }
    println!("\nimage quality -> early-exit rate -> serving latency (threshold {THRESHOLD})");
    println!("{}", table.render());
    println!("{}", coordinator.metrics().summary());
    coordinator.shutdown();
    Ok(())
}
