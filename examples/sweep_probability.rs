//! Fig. 4-style sweep through the public API: how the optimal expected
//! inference time and the chosen split react to the side-branch exit
//! probability, per network technology, at a chosen gamma. The sweep
//! runs through `experiments::fig4`, which plans via the
//! [`branchyserve::planner::Planner`] — one precompute per grid point,
//! one O(N) sweep per network.
//!
//!     cargo run --release --example sweep_probability

use std::path::Path;

use branchyserve::config::settings::Flavor;
use branchyserve::experiments::fig4;
use branchyserve::harness::Table;
use branchyserve::model::Manifest;
use branchyserve::network::bandwidth::Profile;
use branchyserve::profiler::{self, ProfileOptions, ProfileReport};
use branchyserve::runtime::InferenceEngine;
use branchyserve::util::timefmt::format_secs;

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let dir = Path::new("artifacts");
    let manifest = Manifest::load(dir)?;

    // Profile (or reuse the cached profile.json).
    let profile_path = dir.join("profile.json");
    let report = if profile_path.exists() {
        ProfileReport::load(&profile_path)?
    } else {
        let engine = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "sweep")?;
        profiler::measure(&engine, ProfileOptions::default())?
    };

    let desc = manifest.to_desc(0.0);
    let curves = fig4::run(&desc, &report.to_delay_profile(1.0), 11, 1e-9);

    for &gamma in &fig4::GAMMAS {
        println!("\n--- gamma = {gamma} (edge {gamma}x slower than cloud) ---");
        let mut table = Table::new(&["p", "3G", "4G", "WiFi", "3G split", "4G split", "WiFi split"]);
        let get = |net: Profile| {
            curves
                .iter()
                .find(|c| c.gamma == gamma && c.network == net)
                .unwrap()
        };
        let (c3, c4, cw) = (get(Profile::ThreeG), get(Profile::FourG), get(Profile::WiFi));
        for i in 0..c3.points.len() {
            let lbl = |s: usize| {
                if s == 0 {
                    "input".to_string()
                } else {
                    desc.stage_names[s - 1].clone()
                }
            };
            table.row(vec![
                format!("{:.1}", c3.points[i].0),
                format_secs(c3.points[i].1),
                format_secs(c4.points[i].1),
                format_secs(cw.points[i].1),
                lbl(c3.points[i].2),
                lbl(c4.points[i].2),
                lbl(cw.points[i].2),
            ]);
        }
        println!("{}", table.render());
        println!(
            "inference-time reduction p=0 -> p=1:  3G {:.1}%   4G {:.1}%   WiFi {:.1}%",
            c3.reduction_pct(),
            c4.reduction_pct(),
            cw.reduction_pct()
        );
    }
    Ok(())
}
