//! Sharded multi-class fleet demo: one fleet serving a 3G/4G/WiFi client
//! mix, each class behind its own planner (so each runs its own
//! partition point), each class group sharded across several edge/cloud
//! pipelines. Runs on the simulated backend — no artifacts needed:
//!
//!     cargo run --release --example fleet_mixed_links
//!
//! Environment knobs: RATE_RPS (total offered, default 90), DURATION_S
//! (5), SHARDS (2), CLOUD_WORKERS (2), STAGE_COST_US (200),
//! THRESHOLD (0.35), GAMMA (50).

use std::sync::Arc;
use std::time::{Duration, Instant};

use branchyserve::fleet::{ClassRegistry, Fleet, FleetConfig, LinkClass, RoutePolicy};
use branchyserve::model::Manifest;
use branchyserve::profiler::{self, ProfileOptions};
use branchyserve::runtime::InferenceEngine;
use branchyserve::util::rng::Pcg32;
use branchyserve::util::timefmt::{format_rate, format_secs};
use branchyserve::workload::ImageSource;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let rate = env_f64("RATE_RPS", 90.0);
    let duration = Duration::from_secs_f64(env_f64("DURATION_S", 5.0));
    let shards = env_f64("SHARDS", 2.0) as usize;
    let cloud_workers = env_f64("CLOUD_WORKERS", 2.0) as usize;
    let stage_cost = Duration::from_micros(env_f64("STAGE_COST_US", 200.0) as u64);
    let threshold = env_f64("THRESHOLD", 0.35) as f32;
    let gamma = env_f64("GAMMA", 50.0);

    // Simulated model, kept small so 3G transfers stay sub-second.
    let manifest = Manifest::synthetic_sim(
        "sim-balexnet",
        vec![3, 32, 32],
        &[2048, 1024, 512, 128, 2],
        1,
        2,
        vec![1, 2, 4, 8],
    )?;

    // Measure the sim's per-stage times like a deployment would profile
    // its cloud node.
    let probe = InferenceEngine::open_sim_with_cost(manifest.clone(), "profile", stage_cost)?;
    let delay = profiler::measure(&probe, ProfileOptions::default())?.to_delay_profile(gamma);

    let registry = ClassRegistry::builtin(); // 3G / 4G / WiFi
    let m = manifest.clone();
    let fleet = Arc::new(Fleet::start(
        registry,
        &manifest,
        &delay,
        FleetConfig {
            shards_per_class: shards,
            cloud_workers_per_shard: cloud_workers,
            routing: RoutePolicy::LeastLoaded,
            entropy_threshold: threshold,
            batch_timeout: Duration::from_millis(2),
            ..Default::default()
        },
        move |label| {
            Ok((
                InferenceEngine::open_sim_with_cost(m.clone(), &format!("{label}-edge"), stage_cost)?,
                InferenceEngine::open_sim_with_cost(
                    m.clone(),
                    &format!("{label}-cloud"),
                    stage_cost,
                )?,
            ))
        },
    )?);

    println!("fleet: 3 classes x {shards} shard(s) x {cloud_workers} cloud worker(s)");
    for c in &fleet.report().classes {
        println!(
            "  {:>5} @ {:>6.2} Mbps -> split after stage {}",
            c.name, c.link.uplink_mbps, c.split_after
        );
    }

    // Open-loop Poisson mix: 20% 3G, 50% 4G, 30% WiFi.
    let mix = [("3G", 0.20), ("4G", 0.50), ("WiFi", 0.30)];
    let n_clients = 6usize;
    let per_client = rate / n_clients as f64;
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let fleet = fleet.clone();
        let classes: Vec<(LinkClass, f64)> = mix
            .iter()
            .map(|&(name, share)| (fleet.class_by_name(name).unwrap(), share))
            .collect();
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg32::seeded(300 + c as u64);
            let mut source = ImageSource::new(400 + c as u64);
            let start = Instant::now();
            let mut next = start;
            let mut pending = Vec::new();
            let mut rejected = 0u64;
            while start.elapsed() < duration {
                let now = Instant::now();
                if now < next {
                    std::thread::sleep(next - now);
                }
                next += Duration::from_secs_f64(rng.exponential(per_client));
                // Sample the class mix.
                let mut u = rng.f64();
                let mut class = classes[0].0;
                for &(id, share) in &classes {
                    class = id;
                    if u < share {
                        break;
                    }
                    u -= share;
                }
                let (img, _) = source.sample();
                match fleet.submit(class, img) {
                    Ok((_, rx)) => pending.push(rx),
                    Err(_) => rejected += 1,
                }
            }
            let mut completed = 0u64;
            for rx in pending {
                if rx.recv_timeout(Duration::from_secs(30)).is_ok() {
                    completed += 1;
                }
            }
            (completed, rejected)
        }));
    }

    let mut completed = 0u64;
    let mut rejected = 0u64;
    for h in handles {
        let (c, r) = h.join().expect("client thread");
        completed += c;
        rejected += r;
    }

    println!("\n=== mixed-link fleet report ===");
    println!(
        "offered {} for {:.1}s -> completed {completed}, rejected {rejected}, measured {}",
        format_rate(rate),
        duration.as_secs_f64(),
        format_rate(completed as f64 / duration.as_secs_f64()),
    );
    let report = fleet.report();
    println!("{}", report.summary());
    for c in &report.classes {
        println!(
            "  {:>5}: split {} | mean {} | exits {:.1}% | shards completed {:?}",
            c.name,
            c.split_after,
            format_secs(c.aggregate.mean_latency_s),
            c.aggregate.exit_rate() * 100.0,
            c.shards.iter().map(|s| s.completed).collect::<Vec<_>>(),
        );
    }

    let final_report = match Arc::try_unwrap(fleet) {
        Ok(f) => f.shutdown(),
        Err(arc) => arc.report(),
    };
    println!("\nfinal: {}", final_report.total.summary());
    Ok(())
}
