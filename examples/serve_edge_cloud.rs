//! End-to-end serving driver (DESIGN.md's required E2E validation):
//! loads the real AOT-compiled B-AlexNet, plans the optimal partition,
//! starts the edge+cloud coordinator with separate PJRT clients and a
//! simulated 4G uplink, drives it with open-loop Poisson traffic through
//! the TCP front-end, and reports latency/throughput/exit-rate/accuracy.
//!
//!     make artifacts && cargo run --release --example serve_edge_cloud
//!
//! Environment knobs: RATE_RPS (default 30), DURATION_S (10),
//! GAMMA (5), NETWORK (3g), THRESHOLD (0.4).

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use branchyserve::config::settings::Flavor;
use branchyserve::coordinator::{Coordinator, CoordinatorConfig};
use branchyserve::model::Manifest;
use branchyserve::network::bandwidth::{LinkModel, Profile};
use branchyserve::network::Channel;
use branchyserve::planner::Planner;
use branchyserve::profiler::{self, ProfileOptions, ProfileReport};
use branchyserve::runtime::{HostTensor, InferenceEngine};
use branchyserve::server::tcp::Client;
use branchyserve::server::{Request, Response, Server};
use branchyserve::util::rng::Pcg32;
use branchyserve::util::stats::percentile;
use branchyserve::util::timefmt::{format_rate, format_secs};
use branchyserve::workload::ImageSource;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let dir = Path::new("artifacts");
    let rate = env_f64("RATE_RPS", 30.0);
    let duration = Duration::from_secs_f64(env_f64("DURATION_S", 10.0));
    let gamma = env_f64("GAMMA", 5.0);
    let threshold = env_f64("THRESHOLD", 0.4) as f32;
    let net = Profile::parse(&std::env::var("NETWORK").unwrap_or("3g".into()))?;

    // --- model + two nodes (edge, cloud), each with its own PJRT client.
    let manifest = Manifest::load(dir)?;
    let edge = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "edge")?;
    let cloud = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "cloud")?;
    let t0 = Instant::now();
    let compile_s = edge.warmup()? + cloud.warmup()?;
    println!(
        "precompiled {}+{} executables in {:.1}s (xla compile {compile_s:.1}s)",
        edge.cached_count(),
        cloud.cached_count(),
        t0.elapsed().as_secs_f64()
    );

    // --- plan: measured cloud profile, paper gamma model, chosen uplink.
    let profile: ProfileReport = profiler::measure(&edge, ProfileOptions::default())?;
    let delay = profile.to_delay_profile(gamma);
    let link = LinkModel::from_profile(net);
    // Exit probability estimate: measure the branch CDF at the threshold
    // on a held-out batch (what a deployment would calibrate offline).
    let mut calib = ImageSource::new(1234);
    let mut entropies = Vec::new();
    let exec_b = edge.max_batch();
    for _ in 0..4 {
        let (imgs, _) = calib.batch(exec_b);
        let x = HostTensor::stack(&imgs)?;
        let acts = edge.run_stages(1, manifest.branch.after_stage, &x)?;
        entropies.extend(edge.run_branch(&acts)?.entropy);
    }
    let p_est = entropies.iter().filter(|&&e| e < threshold).count() as f64
        / entropies.len() as f64;
    println!("calibrated exit probability at threshold {threshold}: {p_est:.3}");

    let desc = manifest.to_desc(p_est);
    let plan = Planner::new(&desc, &delay, 1e-9, false).plan_for(link);
    println!(
        "plan [{} gamma={gamma}]: split after '{}', predicted E[T] = {}",
        net.name(),
        plan.split_label(&desc),
        format_secs(plan.expected_time_s)
    );

    // --- serving stack: coordinator + TCP front-end.
    let channel = Arc::new(Channel::from_link(link));
    let coordinator = Arc::new(Coordinator::start(
        edge,
        cloud,
        channel,
        plan,
        CoordinatorConfig {
            entropy_threshold: threshold,
            max_batch: exec_b,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 4096,
            ..Default::default()
        },
    ));
    let server = Server::new(coordinator.clone()).start(0)?;
    let addr = server.addr();
    println!("TCP front-end on {addr}");

    // --- open-loop Poisson load over N client connections.
    let n_clients = 4usize;
    let per_client_rate = rate / n_clients as f64;
    let mut handles = Vec::new();
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || -> anyhow::Result<ClientStats> {
            let mut client = Client::connect(addr)?;
            client.ping()?;
            let mut rng = Pcg32::seeded(100 + c as u64);
            let mut source = ImageSource::new(200 + c as u64);
            let start = Instant::now();
            let mut stats = ClientStats::default();
            let mut next = start;
            while start.elapsed() < duration {
                let now = Instant::now();
                if now < next {
                    std::thread::sleep(next - now);
                }
                next += Duration::from_secs_f64(rng.exponential(per_client_rate));
                let (img, label) = source.sample();
                let sent = Instant::now();
                match client.infer(img)? {
                    Response::Result {
                        class,
                        exited_early,
                        ..
                    } => {
                        stats.completed += 1;
                        stats.latencies.push(sent.elapsed().as_secs_f64());
                        if exited_early {
                            stats.exits += 1;
                        }
                        if class as usize == label {
                            stats.correct += 1;
                        }
                    }
                    Response::Error(_) => stats.rejected += 1,
                    other => anyhow::bail!("unexpected response {other:?}"),
                }
            }
            Ok(stats)
        }));
    }

    let mut total = ClientStats::default();
    for h in handles {
        total.merge(h.join().expect("client thread")?);
    }
    let wall = duration.as_secs_f64();

    println!("\n=== end-to-end serving report ===");
    println!("offered rate        {} over {n_clients} connections", format_rate(rate));
    println!("completed           {}", total.completed);
    println!("rejected            {}", total.rejected);
    println!("throughput          {}", format_rate(total.completed as f64 / wall));
    println!(
        "early-exit rate     {:.1}%",
        100.0 * total.exits as f64 / total.completed.max(1) as f64
    );
    println!(
        "accuracy            {:.1}%",
        100.0 * total.correct as f64 / total.completed.max(1) as f64
    );
    if !total.latencies.is_empty() {
        println!(
            "latency mean/p50/p95/p99  {} / {} / {} / {}",
            format_secs(total.latencies.iter().sum::<f64>() / total.latencies.len() as f64),
            format_secs(percentile(&total.latencies, 50.0)),
            format_secs(percentile(&total.latencies, 95.0)),
            format_secs(percentile(&total.latencies, 99.0)),
        );
    }
    println!("coordinator: {}", coordinator.metrics().summary());
    let (bytes, transfers, busy) = coordinator.channel().stats();
    println!("uplink: {bytes} bytes in {transfers} transfers, busy {:.2}s", busy);

    server.stop();
    Ok(())
}

#[derive(Default)]
struct ClientStats {
    completed: u64,
    rejected: u64,
    exits: u64,
    correct: u64,
    latencies: Vec<f64>,
}

impl ClientStats {
    fn merge(&mut self, other: ClientStats) {
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.exits += other.exits;
        self.correct += other.correct;
        self.latencies.extend(other.latencies);
    }
}
