//! Quickstart: plan an optimal BranchyNet partition and run one inference
//! through the partitioned pipeline.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Walks the whole public API surface in ~60 lines: manifest -> profile
//! -> plan (the paper's shortest-path solver) -> coordinator -> inference.

use std::path::Path;
use std::sync::Arc;

use branchyserve::config::settings::Flavor;
use branchyserve::coordinator::{Coordinator, CoordinatorConfig};
use branchyserve::model::Manifest;
use branchyserve::network::bandwidth::{LinkModel, Profile};
use branchyserve::network::Channel;
use branchyserve::partition::solver;
use branchyserve::profiler::{self, ProfileOptions};
use branchyserve::runtime::InferenceEngine;
use branchyserve::util::timefmt::format_secs;
use branchyserve::workload::ImageSource;

fn main() -> anyhow::Result<()> {
    branchyserve::util::logger::init();
    let dir = Path::new("artifacts");

    // 1. Load the AOT-compiled model and measure per-stage cloud times.
    let manifest = Manifest::load(dir)?;
    let engine = InferenceEngine::open(dir, manifest.clone(), Flavor::Ref, "quickstart")?;
    println!("model: {} with {} stages", manifest.model, manifest.num_stages());
    let profile = profiler::measure(&engine, ProfileOptions::default())?;

    // 2. Solve the partitioning problem (paper §V): edge 10x slower than
    //    cloud, 3G uplink, 60% of samples classified at the side branch.
    let gamma = 10.0;
    let exit_probability = 0.6;
    let delay = profile.to_delay_profile(gamma);
    let link = LinkModel::from_profile(Profile::ThreeG);
    let desc = manifest.to_desc(exit_probability);
    let plan = solver::solve(&desc, &delay, link, 1e-9, false);
    println!(
        "optimal split: after '{}' — predicted E[T] = {}",
        plan.split_label(&desc),
        format_secs(plan.expected_time_s)
    );
    let (v_e, v_c) = plan.partition_sets(&desc);
    println!("V_e = {v_e:?}\nV_c = {v_c:?}");

    // 3. Serve one request through the partitioned edge->cloud pipeline.
    let channel = Arc::new(Channel::from_link(link));
    let coordinator = Coordinator::start(
        engine.clone(),
        engine, // share one PJRT client for the quickstart
        channel,
        plan,
        CoordinatorConfig::default(),
    );
    let (image, label) = ImageSource::new(7).sample();
    let response = coordinator.infer_sync(image)?;
    println!(
        "inference: class {} (truth {label}) — {} via {:?}, entropy {:.3}",
        response.class,
        format_secs(response.latency_s),
        response.exit,
        response.entropy
    );
    coordinator.shutdown();
    Ok(())
}
